//! Data-parallel W4A16 comparator (CATLASS-style).
//!
//! Each active AI core owns an output strip `(bm x bn)` end-to-end: its own
//! two vector cores dequantize the strip's weight slice into the workspace
//! and its cube core consumes the slice over the full K range — no K split,
//! no reduce phase.  The FP32 -> FP16 epilogue rides the MTE3 write (the
//! transfer engines do on-the-fly format conversion, §2.3); summation
//! across splits is what *cannot* be done by an MTE, which is why Split-K
//! needs its vector-core Phase 3 while DP does not.
//!
//! Weakness (the paper's §4.1 point): at decode shapes the strip count
//! `ceil(N/bn) * ceil(M/bm)` can be far below the 32 cube cores, leaving
//! compute and MTE bandwidth idle exactly when K is large.

use crate::ascend::{
    BufferClass, ComputeOp, KernelTrace, MachineConfig, Phase, TileStep, Unit,
    WorkspacePolicy,
};

use super::{round_robin_steps, splitk::dequant_phase, tiling::Tiling, GemmProblem};

/// Build the data-parallel trace.
pub fn schedule(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
) -> anyhow::Result<KernelTrace> {
    t.validate(machine, p)?;
    anyhow::ensure!(t.splits == 1, "data-parallel schedule requires S = 1");
    let m_pad = p.m_padded(machine);
    let strips = (m_pad / t.bm) * (p.n / t.bn);
    let active_cores = strips.min(machine.ai_cores);

    // Phase 1: dequant restricted to the active cores' own vector units.
    let p1 = dequant_phase(
        machine,
        p,
        t,
        (active_cores * machine.vector_per_core).min(machine.total_vector_cores()),
        false,
    );

    // Phase 2: full-K GEMM per strip, pipelined against the dequant.
    let k_steps = p.k / t.bk;
    let a_tile = (t.bm * t.bk * 2) as u64;
    let b_tile = (t.bk * t.bn * 2) as u64;
    let out_tile = (t.bm * t.bn * 2) as u64; // f16 via MTE3 on-the-fly cast
    let mid_step = TileStep::new(ComputeOp::Mmad { m: t.bm, n: t.bn, k: t.bk })
        .with_burst((t.bn * 2) as u64)
        .read(BufferClass::Workspace, b_tile)
        .read(BufferClass::Activation, a_tile);
    let last_step = mid_step.write(BufferClass::Output, out_tile);
    let steps_per_engine =
        round_robin_steps(strips, machine.ai_cores, k_steps, mid_step, last_step);
    let p2 = Phase {
        name: "dp_mmad",
        unit: Unit::Cube,
        steps_per_engine,
        pipelined_with_prev: true,
        chunk: None,
    };

    Ok(KernelTrace {
        name: format!("dp_m{}_n{}_k{}", p.m, p.n, p.k),
        phases: vec![p1, p2],
        workspace_bytes: p.f16_weight_bytes(),
        partial_bytes: 0,
        workspace_policy: WorkspacePolicy::Buffered,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;
    use crate::kernels::tiling;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    fn build(mm: usize, n: usize, k: usize) -> (GemmProblem, KernelTrace) {
        let p = GemmProblem::new(mm, n, k);
        let t = tiling::select_data_parallel(&m(), &p).unwrap();
        (p, schedule(&m(), &p, &t).unwrap())
    }

    #[test]
    fn two_phases_no_reduce() {
        let (_, tr) = build(16, 2048, 7168);
        assert_eq!(tr.phases.len(), 2);
        assert_eq!(tr.partial_bytes, 0);
        assert!(tr.phases[1].pipelined_with_prev);
    }

    #[test]
    fn low_occupancy_at_decode_shapes() {
        // N=1024, M<=16: only 4 strips of 256 -> 4 of 32 cube cores busy.
        let (_, tr) = build(8, 1024, 16384);
        assert_eq!(tr.phases[1].active_engines(), 4);
    }

    #[test]
    fn full_occupancy_when_n_large() {
        let (_, tr) = build(8, 12288, 5120);
        assert_eq!(tr.phases[1].active_engines(), 32);
    }

    #[test]
    fn covers_all_macs() {
        let (p, tr) = build(16, 2048, 7168);
        assert_eq!(tr.total_macs(), p.macs(&m()));
    }

    #[test]
    fn writes_f16_output_directly() {
        let (p, tr) = build(16, 1024, 4096);
        assert_eq!(
            tr.phases[1].write_bytes(BufferClass::Output),
            (p.m_padded(&m()) * p.n * 2) as u64
        );
        assert_eq!(tr.phases[1].write_bytes(BufferClass::Partial), 0);
    }

    #[test]
    fn splitk_beats_dp_when_k_dominant() {
        // The paper's Figure 2 headline, as a unit test.
        let machine = m();
        let sim = Simulator::new(machine.clone());
        let p = GemmProblem::new(8, 1024, 16384);
        let t_dp = tiling::select_data_parallel(&machine, &p).unwrap();
        let dp_ns = sim.run(&schedule(&machine, &p, &t_dp).unwrap()).unwrap().total_ns;
        let t_sk = tiling::select_splitk(&machine, &p).unwrap();
        let sk = crate::kernels::splitk::schedule(&machine, &p, &t_sk).unwrap();
        let sk_ns = sim.run(&sk).unwrap().total_ns;
        let speedup = dp_ns / sk_ns;
        assert!(speedup > 1.0, "expected Split-K win, got {speedup:.3}");
    }
}
