//! Benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup + timed iterations, robust summary statistics, aligned output
//! rows, and optional JSON dumps for EXPERIMENTS.md.

pub mod diff;

use std::time::Instant;

use crate::util::stats::{self, Summary};

/// One measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary_ns: Summary,
}

impl BenchResult {
    pub fn render_row(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p50 {:>12}, p90 {:>12}, n={})",
            self.name,
            stats::fmt_ns(self.summary_ns.mean),
            stats::fmt_ns(self.summary_ns.p50),
            stats::fmt_ns(self.summary_ns.p90),
            self.iters,
        )
    }
}

/// Timed-run builder.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench { name: name.into(), warmup: 3, iters: 20 }
    }

    pub fn warmup(mut self, w: usize) -> Bench {
        self.warmup = w;
        self
    }

    pub fn iters(mut self, n: usize) -> Bench {
        self.iters = n.max(1);
        self
    }

    /// Run `f` warmup + iters times, timing each call.
    pub fn run<F: FnMut()>(self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        BenchResult {
            name: self.name,
            iters: self.iters,
            summary_ns: Summary::of(&samples),
        }
    }
}

/// Print a bench-section header (keeps `cargo bench` output scannable).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Standard entry: print each result row as it lands.
pub fn report(result: &BenchResult) {
    println!("{}", result.render_row());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_exactly_warmup_plus_iters() {
        let mut count = 0;
        let r = Bench::new("t").warmup(2).iters(5).run(|| count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.summary_ns.mean >= 0.0);
    }

    #[test]
    fn row_renders() {
        let r = Bench::new("demo").warmup(0).iters(3).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(r.render_row().contains("demo"));
    }
}
