//! Bench-trajectory comparator: diffs a machine-readable `BENCH_*.json`
//! document against a committed baseline and gates on regressions.
//!
//! The simulator is deterministic (pure f64 arithmetic, no wall-clock
//! anywhere in the JSON the benches emit), so a tight relative threshold
//! is safe: any simulated-cycle cell that grows by more than the
//! threshold is a real behavioral regression, not noise.
//!
//! What gates: numeric leaves whose key ends in `_ns` or `_us` — the
//! simulated-latency cells — where *lower is better*.  Keys that name
//! gains, slack, deltas, overlap internals or counterfactual plans
//! (`gain`, `slack`, `vs_`, `reduce`, `merged`, `barrier`, `resident`)
//! are direction-ambiguous and never gated.  Cells present
//! in the baseline but missing from the current run fail the gate (a
//! silently dropped cell is how a trajectory gate rots); new cells are
//! allowed (benches grow columns across PRs).
//!
//! Baselines bootstrap: a committed baseline with `"bootstrap": true`
//! (and no cells) records intent without numbers — the comparator reports
//! but passes, and `repro bench-diff --bless` writes the current run over
//! the baseline so the next PR enforces it.

use crate::util::json::Json;

/// Default regression threshold: 2% (the sim is deterministic).
pub const DEFAULT_THRESHOLD: f64 = 0.02;

/// One compared time cell.
#[derive(Debug, Clone, PartialEq)]
pub struct CellDiff {
    /// JSON path of the cell, e.g. `cells[3].step_us`.
    pub path: String,
    pub baseline: f64,
    pub current: f64,
}

impl CellDiff {
    /// current / baseline (lower is better; >1 is slower).
    pub fn ratio(&self) -> f64 {
        if self.baseline.abs() < 1e-12 {
            if self.current.abs() < 1e-12 {
                1.0
            } else {
                f64::INFINITY
            }
        } else {
            self.current / self.baseline
        }
    }
}

/// Outcome of one baseline-vs-current comparison.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    pub threshold: f64,
    /// Baseline had `"bootstrap": true` — report-only, never gate.
    pub bootstrap: bool,
    /// Gated time cells compared.
    pub checked: usize,
    /// Cells slower than `baseline * (1 + threshold)`.
    pub regressions: Vec<CellDiff>,
    /// Cells faster than `baseline * (1 - threshold)` (informational).
    pub improvements: Vec<CellDiff>,
    /// Baseline cells absent (or non-numeric) in the current run.
    pub missing: Vec<String>,
}

impl DiffReport {
    /// Whether the CI gate passes.
    pub fn gate_passes(&self) -> bool {
        self.bootstrap || (self.regressions.is_empty() && self.missing.is_empty())
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        if self.bootstrap {
            out.push_str(
                "baseline is a bootstrap placeholder — report only, gate passes; \
                 run `repro bench-diff --bless` and commit the baseline to arm the gate\n",
            );
        }
        out.push_str(&format!(
            "checked {} time cells at {:.1}% threshold: {} regressions, {} improvements, \
             {} missing\n",
            self.checked,
            self.threshold * 100.0,
            self.regressions.len(),
            self.improvements.len(),
            self.missing.len(),
        ));
        for c in &self.regressions {
            out.push_str(&format!(
                "  REGRESSION {}: {:.3} -> {:.3} ({:+.2}%)\n",
                c.path,
                c.baseline,
                c.current,
                (c.ratio() - 1.0) * 100.0,
            ));
        }
        for path in &self.missing {
            out.push_str(&format!("  MISSING {path}: baseline cell absent from current run\n"));
        }
        for c in &self.improvements {
            out.push_str(&format!(
                "  improvement {}: {:.3} -> {:.3} ({:+.2}%)\n",
                c.path,
                c.baseline,
                c.current,
                (c.ratio() - 1.0) * 100.0,
            ));
        }
        out.push_str(if self.gate_passes() { "gate: PASS\n" } else { "gate: FAIL\n" });
        out
    }

    pub fn to_json(&self) -> Json {
        let cell = |c: &CellDiff| {
            Json::obj(vec![
                ("path", Json::str(c.path.clone())),
                ("baseline", Json::num(c.baseline)),
                ("current", Json::num(c.current)),
                ("ratio", Json::num(c.ratio())),
            ])
        };
        Json::obj(vec![
            ("threshold", Json::num(self.threshold)),
            ("bootstrap", Json::Bool(self.bootstrap)),
            ("checked", Json::num(self.checked as f64)),
            ("gate_passes", Json::Bool(self.gate_passes())),
            ("regressions", Json::arr(self.regressions.iter().map(cell).collect())),
            ("improvements", Json::arr(self.improvements.iter().map(cell).collect())),
            (
                "missing",
                Json::arr(self.missing.iter().map(|p| Json::str(p.clone())).collect()),
            ),
        ])
    }
}

/// Whether a key names a gated simulated-latency cell (lower is better).
/// Direction-ambiguous cells are excluded: gains/slack/deltas grow when
/// the overlap improves, exposed-reduce cells (`reduce_ns`,
/// `reduce_tail_ns`) can legitimately grow when the tail is then hidden,
/// `exact_merged_ns` is Null whenever a pair stops being spliceable (a
/// schema change, not a regression), and `barrier_ns`/`layer_barrier_us`
/// price a *counterfactual* schedule that a better tuner pick may
/// legitimately worsen while the served plan improves.
///
/// The PR-5 residency cells follow the same rule: the *resident-plan*
/// price (`step_resident_us`, `resident_ns`) is a counterfactual — the
/// served plan is `min(PR-4 plan, resident plan)`, so a better tuner
/// pick can legitimately snap the resident price back to its unpinned
/// baseline while the served latency improves — and is excluded like
/// `barrier_ns`.  The served latency (`step_us`) already folds the
/// residency min in, so a genuine residency regression still gates
/// there.  `residency_gain_us` / `residency_speedup` /
/// `residency_pinned_bytes` / `chain_gain_ns` are gains, ratios or
/// byte counts and never gate.
///
/// The precision-sweep cells (DESIGN.md §16) need no special case:
/// `w4a16_us` and `w4a8_us` are absolute tuned latencies and gate like
/// any other `_us` cell; `w4a8_speedup` is a ratio of the two (it moves
/// whenever either column legitimately improves) and never gates.
///
/// Wall-clock cells (`*wall*`, the `sim_perf` serial-vs-pooled timings)
/// measure the HOST machine, not the simulated NPU — they vary with CI
/// hardware and load and must never gate.
///
/// The preemption-leg cells (DESIGN.md §18) gate on their *overhead*
/// columns: `preempt_swap_us` and `preempt_recompute_us` are virtual
/// microseconds the policy spent recovering victims — lower is strictly
/// better at a fixed leg config, so they gate like any latency cell.
/// The *ledger* columns (`preempted`, `resumed`, `swap_bytes`,
/// `recompute_ticks`) are event counts with no time suffix and never
/// gate — a policy change legitimately moves how often preemption fires;
/// the cost of firing is what must not regress.  `max_wait_us` is the
/// leg's anti-starvation window — a config knob echoed into the cell for
/// self-description, not a measurement — and is excluded by name.
pub fn is_gated_time_cell(key: &str) -> bool {
    let timed = key.ends_with("_ns") || key.ends_with("_us");
    let ambiguous = key.contains("gain")
        || key.contains("slack")
        || key.contains("vs_")
        || key.contains("reduce")
        || key.contains("merged")
        || key.contains("barrier")
        || key.contains("resident")
        || key.contains("wall")
        || key.contains("max_wait");
    timed && !ambiguous
}

/// Compare `current` against `baseline` at a relative `threshold`.
pub fn diff(baseline: &Json, current: &Json, threshold: f64) -> DiffReport {
    let mut report = DiffReport {
        threshold,
        bootstrap: baseline
            .get("bootstrap")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        ..DiffReport::default()
    };
    walk("", baseline, current, &mut report);
    report
}

fn walk(path: &str, baseline: &Json, current: &Json, report: &mut DiffReport) {
    match baseline {
        Json::Obj(map) => {
            for (key, base_val) in map {
                let child = if path.is_empty() {
                    key.clone()
                } else {
                    format!("{path}.{key}")
                };
                let cur_val = current.get(key);
                if is_gated_time_cell(key) {
                    if let Some(base) = base_val.as_f64() {
                        match cur_val.and_then(Json::as_f64) {
                            Some(cur) => compare(child, base, cur, report),
                            None => report.missing.push(child),
                        }
                        continue;
                    }
                }
                match cur_val {
                    Some(cur) => walk(&child, base_val, cur, report),
                    None => {
                        if subtree_has_time_cells(base_val) {
                            report.missing.push(child);
                        }
                    }
                }
            }
        }
        Json::Arr(items) => {
            let empty = Vec::new();
            let cur_items = current.as_arr().unwrap_or(&empty);
            // Bench cell arrays carry (model, batch) identities: match by
            // identity so inserting or reordering sweep entries shifts
            // nothing.  Arrays without identities (node lists, overlap
            // entries) align by index — there, order IS the schema.
            let by_identity = !items.is_empty() && items.iter().all(|v| cell_identity(v).is_some());
            if by_identity {
                let mut used = vec![false; cur_items.len()];
                for base_val in items {
                    let id = cell_identity(base_val).unwrap();
                    let child = format!("{path}[{id}]");
                    let found = cur_items.iter().enumerate().find(|(i, v)| {
                        !used[*i] && cell_identity(v).as_deref() == Some(id.as_str())
                    });
                    match found {
                        Some((i, cur)) => {
                            used[i] = true;
                            walk(&child, base_val, cur, report);
                        }
                        None => {
                            if subtree_has_time_cells(base_val) {
                                report.missing.push(child);
                            }
                        }
                    }
                }
            } else {
                for (i, base_val) in items.iter().enumerate() {
                    let child = format!("{path}[{i}]");
                    match cur_items.get(i) {
                        Some(cur) => walk(&child, base_val, cur, report),
                        None => {
                            if subtree_has_time_cells(base_val) {
                                report.missing.push(child);
                            }
                        }
                    }
                }
            }
        }
        _ => {}
    }
}

/// A bench cell's identity, when it has one: `model` plus the optional
/// `batch` (the e2e_layer / ablation sweeps key their cells this way).
fn cell_identity(v: &Json) -> Option<String> {
    let model = v.get("model")?.as_str()?;
    match v.get("batch").and_then(Json::as_f64) {
        Some(b) => Some(format!("{model} b{b}")),
        None => Some(model.to_string()),
    }
}

fn subtree_has_time_cells(v: &Json) -> bool {
    match v {
        Json::Obj(map) => map
            .iter()
            .any(|(k, v)| (is_gated_time_cell(k) && v.as_f64().is_some()) || subtree_has_time_cells(v)),
        Json::Arr(items) => items.iter().any(subtree_has_time_cells),
        _ => false,
    }
}

fn compare(path: String, baseline: f64, current: f64, report: &mut DiffReport) {
    report.checked += 1;
    let cell = CellDiff { path, baseline, current };
    // An exact-zero baseline cell compares by absolute epsilon (e.g. a
    // vector node with zero HBM traffic must stay zero).
    if baseline.abs() < 1e-12 {
        if current.abs() > 1e-9 {
            report.regressions.push(cell);
        }
        return;
    }
    if current > baseline * (1.0 + report.threshold) {
        report.regressions.push(cell);
    } else if current < baseline * (1.0 - report.threshold) {
        report.improvements.push(cell);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(step_us: f64, extra: Option<(&str, f64)>) -> Json {
        let mut cell = vec![
            ("model", Json::str("glm45")),
            ("step_us", Json::num(step_us)),
            ("overlap_speedup", Json::num(1.05)),
            ("overlap_gain_us", Json::num(3.0)),
        ];
        if let Some((k, v)) = extra {
            cell.push((k, Json::num(v)));
        }
        Json::obj(vec![
            ("bench", Json::str("e2e_layer")),
            ("cells", Json::arr(vec![Json::obj(cell)])),
        ])
    }

    #[test]
    fn identical_documents_pass() {
        let r = diff(&doc(100.0, None), &doc(100.0, None), DEFAULT_THRESHOLD);
        assert!(r.gate_passes());
        assert_eq!(r.checked, 1, "only the time cell is gated");
        assert!(r.regressions.is_empty() && r.improvements.is_empty() && r.missing.is_empty());
    }

    #[test]
    fn injected_regression_fails_the_gate() {
        // The acceptance demo: a >2% simulated-cycle regression trips it.
        let r = diff(&doc(100.0, None), &doc(103.0, None), DEFAULT_THRESHOLD);
        assert!(!r.gate_passes());
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].path, "cells[0].step_us");
        assert!((r.regressions[0].ratio() - 1.03).abs() < 1e-9);
        assert!(r.render().contains("REGRESSION"));
        // Within threshold passes.
        assert!(diff(&doc(100.0, None), &doc(101.9, None), DEFAULT_THRESHOLD).gate_passes());
    }

    #[test]
    fn improvements_pass_and_are_reported() {
        let r = diff(&doc(100.0, None), &doc(80.0, None), DEFAULT_THRESHOLD);
        assert!(r.gate_passes());
        assert_eq!(r.improvements.len(), 1);
    }

    #[test]
    fn residency_cells_classify_as_designed() {
        // The resident-plan price is a counterfactual (served is
        // min(PR-4 plan, resident plan)) and never gates — like
        // barrier_ns; neither do the plan's side channels (gain, speedup
        // ratio, pinned bytes).  The served step_us folds the residency
        // min in, so residency regressions still gate there.
        assert!(!is_gated_time_cell("step_resident_us"));
        assert!(!is_gated_time_cell("resident_ns"));
        assert!(!is_gated_time_cell("residency_gain_us"));
        assert!(!is_gated_time_cell("residency_gain_ns"));
        assert!(!is_gated_time_cell("residency_speedup"));
        assert!(!is_gated_time_cell("residency_pinned_bytes"));
        assert!(!is_gated_time_cell("chain_gain_ns"));
        // A snapped-back resident price alone never trips the gate...
        let base = doc(100.0, Some(("step_resident_us", 50.0)));
        let cur = doc(100.0, Some(("step_resident_us", 60.0)));
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.gate_passes(), "{}", r.render());
        assert_eq!(r.checked, 1, "only step_us gates");
        // ...but a lost residency win shows up in the served cell.
        let r = diff(&doc(50.0, None), &doc(60.0, None), DEFAULT_THRESHOLD);
        assert!(!r.gate_passes());
        assert_eq!(r.regressions[0].path, "cells[0].step_us");
    }

    #[test]
    fn precision_sweep_cells_classify_as_designed() {
        // Both tuned latency columns gate (a slower W4A8 winner is a
        // real regression even while W4A16 holds); the ratio never does.
        assert!(is_gated_time_cell("w4a16_us"));
        assert!(is_gated_time_cell("w4a8_us"));
        assert!(!is_gated_time_cell("w4a8_speedup"));
        // A >2% regression in the W4A8 column trips the gate on its own.
        let base = doc(100.0, Some(("w4a8_us", 50.0)));
        let cur = doc(100.0, Some(("w4a8_us", 53.0)));
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!r.gate_passes());
        assert_eq!(r.regressions[0].path, "cells[0].w4a8_us");
        // A moved speedup ratio alone is fine.
        let base = doc(100.0, Some(("w4a8_speedup", 1.4)));
        let cur = doc(100.0, Some(("w4a8_speedup", 1.1)));
        assert!(diff(&base, &cur, DEFAULT_THRESHOLD).gate_passes());
    }

    #[test]
    fn preemption_cells_classify_as_designed() {
        // Recovery-overhead columns are simulated time and gate; ledger
        // counts and the echoed config knob never do.
        assert!(is_gated_time_cell("preempt_swap_us"));
        assert!(is_gated_time_cell("preempt_recompute_us"));
        assert!(!is_gated_time_cell("preempted"));
        assert!(!is_gated_time_cell("resumed"));
        assert!(!is_gated_time_cell("swap_bytes"));
        assert!(!is_gated_time_cell("recompute_ticks"));
        assert!(!is_gated_time_cell("max_wait_us"));
        // A >2% jump in the recompute bill trips the gate on its own...
        let base = doc(100.0, Some(("preempt_recompute_us", 400.0)));
        let cur = doc(100.0, Some(("preempt_recompute_us", 450.0)));
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!r.gate_passes());
        assert_eq!(r.regressions[0].path, "cells[0].preempt_recompute_us");
        // ...while a 10x swing in the preemption ledger passes untouched.
        let base = doc(100.0, Some(("swap_bytes", 4.0e6)));
        let cur = doc(100.0, Some(("swap_bytes", 4.0e7)));
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.gate_passes(), "{}", r.render());
        assert_eq!(r.checked, 1, "only step_us gates");
    }

    #[test]
    fn wall_clock_cells_never_gate() {
        // Host wall-clock timings (the sim_perf serial-vs-pooled legs)
        // track the CI machine, not the simulated NPU: a 10x swing in a
        // `*_wall_us` cell must pass the gate untouched.
        assert!(!is_gated_time_cell("tune_serial_wall_us"));
        assert!(!is_gated_time_cell("tune_pooled_wall_us"));
        assert!(!is_gated_time_cell("prefix_serial_wall_us"));
        assert!(!is_gated_time_cell("prefix_pooled_wall_us"));
        assert!(is_gated_time_cell("step_us"), "real sim cells still gate");
        let base = doc(100.0, Some(("prefix_pooled_wall_us", 40.0)));
        let cur = doc(100.0, Some(("prefix_pooled_wall_us", 400.0)));
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.gate_passes(), "{}", r.render());
        assert_eq!(r.checked, 1, "only step_us gates");
    }

    #[test]
    fn gain_slack_and_speedup_cells_never_gate() {
        // overlap_gain_us grows 10x and overlap_speedup moves: both fine.
        let base = doc(100.0, Some(("dequant_slack_ns", 5.0)));
        let mut cur = doc(100.0, Some(("dequant_slack_ns", 50.0)));
        if let Json::Obj(m) = &mut cur {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.insert("overlap_gain_us".into(), Json::num(30.0));
                }
            }
        }
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.gate_passes(), "{}", r.render());
        assert_eq!(r.checked, 1);
    }

    #[test]
    fn direction_ambiguous_reduce_and_merged_cells_never_gate() {
        // A grown exposed-reduce tail and a pair that stopped being
        // spliceable (exact_merged_ns number -> Null) are schema/ledger
        // movements, not latency regressions.
        let base = doc(100.0, Some(("reduce_ns", 10.0)));
        let cur = doc(100.0, Some(("reduce_ns", 100.0)));
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.gate_passes(), "{}", r.render());
        assert_eq!(r.checked, 1);
        let base = doc(100.0, Some(("exact_merged_ns", 40.0)));
        let cur = doc(100.0, None); // the key is simply gone / Null now
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.gate_passes(), "{}", r.render());
    }

    #[test]
    fn missing_baseline_cells_fail_new_cells_pass() {
        // Baseline carries a cell the current run dropped.
        let base = doc(100.0, Some(("layer_us", 40.0)));
        let cur = doc(100.0, None);
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!r.gate_passes());
        assert_eq!(r.missing, vec!["cells[0].layer_us"]);
        // The other direction (current grew a column) passes.
        let r = diff(&cur, &base, DEFAULT_THRESHOLD);
        assert!(r.gate_passes());
    }

    #[test]
    fn cells_match_by_model_and_batch_not_index() {
        // The current run inserted a new model BEFORE the baseline's cell:
        // identity matching still pairs glm45-with-glm45.
        let base = doc(100.0, None);
        let newcomer = Json::obj(vec![
            ("model", Json::str("new-model")),
            ("step_us", Json::num(999.0)),
        ]);
        let mut cur = doc(100.0, None);
        if let Json::Obj(m) = &mut cur {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                cells.insert(0, newcomer);
            }
        }
        let r = diff(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.gate_passes(), "{}", r.render());
        assert_eq!(r.checked, 1);
        // A baseline cell whose identity disappears entirely is missing.
        let gone = Json::obj(vec![
            ("bench", Json::str("e2e_layer")),
            ("cells", Json::arr(vec![Json::obj(vec![
                ("model", Json::str("other")),
                ("step_us", Json::num(5.0)),
            ])])),
        ]);
        let r = diff(&base, &gone, DEFAULT_THRESHOLD);
        assert!(!r.gate_passes());
        assert_eq!(r.missing.len(), 1);
    }

    #[test]
    fn bootstrap_baseline_reports_but_passes() {
        let base = Json::obj(vec![
            ("bench", Json::str("e2e_layer")),
            ("bootstrap", Json::Bool(true)),
            ("cells", Json::arr(vec![])),
        ]);
        let r = diff(&base, &doc(100.0, None), DEFAULT_THRESHOLD);
        assert!(r.gate_passes());
        assert!(r.bootstrap);
        assert!(r.render().contains("bootstrap"));
    }

    #[test]
    fn zero_baseline_cells_must_stay_zero() {
        let base = doc(0.0, None);
        assert!(diff(&base, &doc(0.0, None), DEFAULT_THRESHOLD).gate_passes());
        assert!(!diff(&base, &doc(1.0, None), DEFAULT_THRESHOLD).gate_passes());
    }

    #[test]
    fn report_json_round_trips() {
        let r = diff(&doc(100.0, None), &doc(110.0, None), DEFAULT_THRESHOLD);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.req("gate_passes").unwrap().as_bool(), Some(false));
        assert_eq!(j.req("regressions").unwrap().as_arr().unwrap().len(), 1);
    }
}
