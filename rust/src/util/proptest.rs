//! Miniature property-testing kit (proptest is unavailable offline).
//!
//! `forall` runs a property over many PRNG-generated cases; failures report
//! the case index and seed so they can be replayed exactly:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath in this image)
//! use ascend_w4a16::util::proptest::forall;
//! forall("addition commutes", 100, |rng| {
//!     let (a, b) = (rng.next_u64() as u32, rng.next_u64() as u32);
//!     let ok = a.wrapping_add(b) == b.wrapping_add(a);
//!     (ok, format!("a={a} b={b}"))
//! });
//! ```

use super::prng::Rng;

/// Base seed; override with `PROPTEST_SEED` to replay a failing run.
fn base_seed() -> u64 {
    std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA5CE_4D91)
}

/// Case-count multiplier; override with `PROPTEST_MULT` (the nightly CI
/// job runs the whole property suite at 25x depth — same seeds first, so
/// any failure it finds beyond the default depth is still replayable via
/// `PROPTEST_SEED`).
fn case_mult() -> u32 {
    std::env::var("PROPTEST_MULT")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&m| m >= 1)
        .unwrap_or(1)
}

/// Run `prop` against `cases` generated inputs (scaled by
/// `PROPTEST_MULT`).  The property returns `(holds, description)`; on
/// failure, panics with the replay seed.
pub fn forall<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Rng) -> (bool, String),
{
    let seed0 = base_seed();
    let cases = cases.saturating_mul(case_mult());
    for case in 0..cases {
        let seed = seed0.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let (ok, desc) = prop(&mut rng);
        assert!(
            ok,
            "property '{name}' failed on case {case} (PROPTEST_SEED={seed}): {desc}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 50, |_| {
            count += 1;
            (true, String::new())
        });
        // The nightly job scales depth via PROPTEST_MULT; the default is 1.
        assert_eq!(count, 50 * case_mult());
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        forall("fails", 10, |rng| {
            let x = rng.usize_range(0, 9);
            (x < 5, format!("x={x}"))
        });
    }
}
