//! Summary statistics for bench results and serving metrics.

/// Summary of a sample of measurements (times, sizes, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0, mean: 0.0, stddev: 0.0, min: 0.0, max: 0.0,
                p50: 0.0, p90: 0.0, p99: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for speedup aggregation across shapes).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Format a nanosecond quantity with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Format a byte quantity with an adaptive unit.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / 1024.0 / 1024.0)
    } else {
        format!("{:.2} GiB", b / 1024.0 / 1024.0 / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_bytes(2048.0), "2.0 KiB");
    }
}
