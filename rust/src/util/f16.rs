//! IEEE 754 binary16 <-> binary32 conversion.
//!
//! The W4A16 pipeline keeps activations and dequantized weights in FP16;
//! the rust side needs bit-exact conversions to prepare PJRT literals and
//! to check artifact outputs against host references.

/// Convert an f32 to its IEEE binary16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: preserve a quiet-NaN payload bit if any mantissa bit set.
        let nan = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | nan;
    }
    // Re-bias: f32 exp-127 == f16 exp-15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal range: keep top 10 mantissa bits, round to nearest even.
        let mant16 = mant >> 13;
        let rest = mant & 0x1FFF;
        let mut h = sign | (((unbiased + 15) as u16) << 10) | mant16 as u16;
        if rest > 0x1000 || (rest == 0x1000 && (mant16 & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent: still correct
        }
        return h;
    }
    if unbiased >= -25 {
        // Subnormal f16: shift mantissa (with implicit 1) into place.
        let full = mant | 0x80_0000;
        let shift = (-unbiased - 14 + 13) as u32;
        let mant16 = (full >> shift) as u16;
        let rest = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign | mant16;
        if rest > half || (rest == half && (mant16 & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return h;
    }
    sign // underflow -> signed zero
}

/// Convert an IEEE binary16 bit pattern to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: value = mant * 2^-24. Normalize by shifting until
            // the implicit bit (0x400) is set; the exponent drops per shift.
            let mut shifts = 0u32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                shifts += 1;
            }
            m &= 0x3FF;
            sign | ((113 - shifts) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision (quantize-to-f16 then widen).
pub fn round_to_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Convert a slice of f32 to packed little-endian f16 bytes (PJRT literal payload).
pub fn f32_slice_to_f16_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
    out
}

/// Convert packed little-endian f16 bytes back to f32s.
pub fn f16_bytes_to_f32_vec(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16 max
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00); // overflow -> inf
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
    }

    #[test]
    fn round_trip_exact_for_f16_values() {
        for h in 0..=0xFFFFu16 {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(f)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(f), h, "bits 0x{h:04x}");
            }
        }
    }

    #[test]
    fn subnormals() {
        let smallest = f16_bits_to_f32(0x0001);
        assert!((smallest - 5.960_464_5e-8).abs() < 1e-12);
        assert_eq!(f32_to_f16_bits(smallest), 0x0001);
    }

    #[test]
    fn rounding_nearest_even() {
        // 1.0 + 2^-11 is exactly between 1.0 and the next f16; ties-to-even -> 1.0
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3C00);
        // slightly above the midpoint rounds up
        let y = 1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20);
        assert_eq!(f32_to_f16_bits(y), 0x3C01);
    }

    #[test]
    fn byte_helpers() {
        let xs = [0.5f32, -1.25, 100.0];
        let bytes = f32_slice_to_f16_bytes(&xs);
        assert_eq!(bytes.len(), 6);
        let back = f16_bytes_to_f32_vec(&bytes);
        assert_eq!(back, vec![0.5, -1.25, 100.0]);
    }
}
