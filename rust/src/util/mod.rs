//! Offline-environment substrates: JSON, CLI parsing, IEEE half-precision
//! conversion, PRNG, statistics and a miniature property-testing kit.
//!
//! The build image has no network access and only the `xla` crate's
//! dependency closure cached, so the usual suspects (serde_json, clap,
//! half, rand, proptest, criterion) are reimplemented here at the size
//! this project actually needs.

pub mod cli;
pub mod f16;
pub mod json;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod stats;
