//! Minimal deterministic fork-join helper (rayon is unavailable offline).
//!
//! `par_map` fans a read-only closure over a slice on scoped OS threads
//! and merges the results **in index order**, so callers observe exactly
//! the output a serial `iter().map().collect()` would produce — the
//! contract the analysis layer's bit-identity guarantees rest on.  Work
//! is claimed from a shared atomic counter, so uneven item costs load-
//! balance without any affinity to which thread computed what.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker threads `par_map` would use for `items` work items: one per
/// available core, never more than the item count, and 1 when the
/// parallelism query fails (serial fallback).
pub fn worker_count(items: usize) -> usize {
    if items <= 1 {
        return items.max(1);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(items)
}

/// Map `f` over `items` on up to [`worker_count`] scoped threads,
/// returning results in input order.  With one worker (or one item) this
/// degenerates to a plain serial map — same closure, same order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = worker_count(items.len());
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut tagged: Vec<(usize, R)> = Vec::with_capacity(items.len());
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        out.push((i, f(&items[i])));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            tagged.extend(h.join().expect("pool worker panicked"));
        }
    });
    tagged.sort_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..97).collect();
        // Uneven per-item work so threads interleave claims.
        let out = par_map(&items, |&i| {
            let mut acc = i as u64;
            for _ in 0..(i % 7) * 1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            let _ = acc;
            i * 3
        });
        assert_eq!(out, items.iter().map(|&i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map(&none, |&x| x).is_empty());
        assert_eq!(par_map(&[41u32], |&x| x + 1), vec![42]);
    }

    #[test]
    fn repeated_runs_are_deterministic() {
        let items: Vec<f64> = (1..64).map(|i| i as f64 * 0.37).collect();
        let a = par_map(&items, |&x| (x.sin() * 1e9).to_bits());
        let b = par_map(&items, |&x| (x.sin() * 1e9).to_bits());
        assert_eq!(a, b);
    }

    #[test]
    fn worker_count_is_bounded_by_items() {
        assert_eq!(worker_count(0), 1);
        assert_eq!(worker_count(1), 1);
        assert!(worker_count(4) <= 4);
        assert!(worker_count(1000) >= 1);
    }
}
