//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, `--key value` options, `--flag` switches
/// and bare positionals, in a form the CLI front end can interrogate.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Parse an enumerated `--name` option against alias groups (the
    /// first alias of each group is canonical).  One shared error message
    /// lists every accepted spelling — the `--overlap` / `--residency` /
    /// `--precision` options all route through here so the CLI rejects
    /// unknown values identically.
    pub fn get_choice<T: Copy>(
        &self,
        name: &str,
        choices: &[(&[&str], T)],
        default: T,
    ) -> anyhow::Result<T> {
        let Some(v) = self.get(name) else {
            return Ok(default);
        };
        let lower = v.to_ascii_lowercase();
        for (aliases, value) in choices {
            if aliases.contains(&lower.as_str()) {
                return Ok(*value);
            }
        }
        let canonical: Vec<&str> = choices.iter().map(|(aliases, _)| aliases[0]).collect();
        anyhow::bail!("--{name} must be one of {} (got '{v}')", canonical.join("|"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("simulate --shape 2048x7168 --batch 8 extra");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("shape"), Some("2048x7168"));
        assert_eq!(a.get_usize("batch", 1).unwrap(), 8);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse("fig2 --verbose --out report.json");
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("report.json"));
        assert!(!a.flag("out"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --port=8080");
        assert_eq!(a.get("port"), Some("8080"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --batch nope");
        assert!(a.get_usize("batch", 1).is_err());
    }

    const MODES: &[(&[&str], u8)] = &[(&["fast", "f"], 0), (&["slow"], 1)];

    #[test]
    fn choice_resolves_aliases_and_defaults() {
        let a = parse("x --mode f");
        assert_eq!(a.get_choice("mode", MODES, 9).unwrap(), 0);
        let a = parse("x --mode SLOW");
        assert_eq!(a.get_choice("mode", MODES, 9).unwrap(), 1);
        let a = parse("x");
        assert_eq!(a.get_choice("mode", MODES, 9).unwrap(), 9);
    }

    #[test]
    fn choice_rejects_with_the_valid_list() {
        let a = parse("x --mode warp");
        let err = a.get_choice("mode", MODES, 9).unwrap_err().to_string();
        assert_eq!(err, "--mode must be one of fast|slow (got 'warp')");
    }
}
