//! Minimal JSON parser + serializer (RFC 8259 subset, UTF-8).
//!
//! Used to read `artifacts/manifest.json` (written by the python AOT
//! pipeline) and to emit machine-readable bench/analysis reports.  The
//! offline build image has no serde_json, so this is a small hand-rolled
//! recursive-descent implementation.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ----- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers: error with the key name on absence/mismatch.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not an array"))
    }

    // ----- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn display_round_trips() {
        let src = r#"{"k":[1,2.5,"x\"y",false,null]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }
}
