//! Deterministic PRNG (xoshiro256**) for synthetic data and property tests.
//!
//! No `rand` crate offline, so the coordinator/workload/test code uses this
//! small, well-known generator.  Not cryptographic — everything here is
//! simulation and test-input generation.

/// xoshiro256** generator (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_range(0, items.len() - 1)]
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of N(0, scale) samples.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Exponential inter-arrival sample with the given rate (events/sec).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_range_bounds() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let x = r.usize_range(3, 6);
            assert!((3..=6).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
