//! End-to-end serving driver (the DESIGN.md E2E deliverable): load the
//! ~100M-parameter W4A16-quantized decode model, serve a batch of
//! synthetic decode requests through the full coordinator stack
//! (queue -> dynamic batcher -> router -> PJRT decode engine), and report
//! latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example llm_decode
//! # faster smoke run:
//! cargo run --release --example llm_decode -- --model tiny --requests 12
//! ```

use ascend_w4a16::coordinator::{BatchPolicy, Batcher, Router, Server};
use ascend_w4a16::model::Engine;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::util::cli::Args;
use ascend_w4a16::workload::RequestGenerator;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "small100m").to_string();
    let n_requests = args.get_usize("requests", 16)?;
    let seed = args.get_usize("seed", 7)? as u64;

    let manifest = Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    let router = Router::new(&rt, manifest, &model)?;
    let sizes = router.batch_sizes();
    println!("model '{model}', compiled batch sizes: {sizes:?}");
    let mut server = Server::new(router, Batcher::new(BatchPolicy::new(sizes)?));

    // Model limits for the request generator.
    let (vocab, max_seq) = {
        let first = *server.router.batch_sizes().first().unwrap();
        let e = server.router.engine(first)?;
        match e {
            Engine::Real(d) => println!(
                "engine ready: {} layers, hidden {}, vocab {}, KV cache {} KiB/group",
                d.layers,
                d.hidden,
                d.vocab,
                d.cache_bytes() / 1024
            ),
            Engine::Synthetic(_) => println!("engine ready: synthetic (weightless artifact)"),
        }
        (e.vocab(), e.max_seq())
    };

    // Submit a burst of synthetic decode requests.
    let mut generator = RequestGenerator::new(seed, vocab, max_seq);
    let requests = generator.burst(n_requests);
    let total_budget: usize = requests.iter().map(|r| r.max_new_tokens).sum();
    println!(
        "submitting {n_requests} requests ({} tokens of generation budget)",
        total_budget
    );
    let t0 = std::time::Instant::now();
    for req in requests {
        server.submit(req);
    }
    let results = server.drain()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== results ==");
    for r in results.iter().take(4) {
        println!(
            "request {:>3}: {} tokens in {:.2}s (ttft {:.2}s) — first 8: {:?}",
            r.id,
            r.tokens.len(),
            r.total_s,
            r.ttft_s,
            &r.tokens[..r.tokens.len().min(8)]
        );
    }
    if results.len() > 4 {
        println!("... ({} more)", results.len() - 4);
    }

    println!("\n== serving metrics ==");
    print!("{}", server.metrics.snapshot().render(wall));
    println!(
        "engines built: {} (one compiled executable per batch size)",
        server.router.engines_built()
    );
    println!("\nNOTE: absolute latency is CPU-PJRT wallclock; the NPU-level \
              latency claims are reproduced by the simulator benches (fig2/fig3).");
    Ok(())
}
