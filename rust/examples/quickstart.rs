//! Quickstart: quantize a weight matrix to packed INT4 in rust, run the
//! AOT-compiled Split-K W4A16 kernel through PJRT, and check the result
//! against the host reference.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ascend_w4a16::quant;
use ascend_w4a16::runtime::client::literal_to_host;
use ascend_w4a16::runtime::{HostTensor, Manifest, Runtime};
use ascend_w4a16::tensor::MatF32;
use ascend_w4a16::util::prng::Rng;
use ascend_w4a16::util::stats;

fn main() -> anyhow::Result<()> {
    // 1. Load the artifact manifest produced by `make artifacts`.
    let manifest = Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let entry = manifest.find("splitk_m16_n2048_k2048")?;
    let (m, n, k) = entry.gemm.unwrap();
    println!("artifact: {} (M={m}, N={n}, K={k}, S={})", entry.name, entry.splits);

    // 2. Quantize a synthetic FP32 weight matrix to group-wise INT4.
    let mut rng = Rng::new(2024);
    let a = MatF32::from_vec(m, k, rng.normal_vec(m * k, 0.5));
    let w = MatF32::from_vec(k, n, rng.normal_vec(k * n, 0.05));
    let qw = quant::quantize_groupwise(&w, manifest.group, false)?;
    println!(
        "weights: {} FP32 -> {} packed INT4 (+{} of scales/zeros)",
        stats::fmt_bytes((k * n * 4) as f64),
        stats::fmt_bytes(qw.packed_bytes() as f64),
        stats::fmt_bytes((qw.scales.len() * 8) as f64),
    );

    // 3. Compile + execute through PJRT (this is the entire serving path —
    //    no Python anywhere).
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load(entry)?;
    let t0 = std::time::Instant::now();
    let out = exe.run(&[
        HostTensor::F32(a.data.clone()),
        HostTensor::I8(qw.packed.clone()),
        HostTensor::F32(qw.scales.clone()),
        HostTensor::F32(qw.zeros.clone()),
    ])?;
    let elapsed = t0.elapsed();

    // 4. Validate against the host reference (dequant + f16-rounded GEMM).
    let got = MatF32::from_vec(m, n, literal_to_host(&out[0])?.as_f32()?);
    let want = quant::w4a16_reference(&a, &qw);
    let err = got.max_abs_diff(&want);
    println!(
        "executed in {} — max |err| vs reference {err:.3e}",
        stats::fmt_ns(elapsed.as_nanos() as f64)
    );
    anyhow::ensure!(got.allclose(&want, 2e-2, 2e-2), "numerics mismatch");
    println!("quickstart OK — C[0][0..4] = {:?}", &got.data[..4]);
    Ok(())
}
