//! §4.2 reproduction: the memory-bottleneck analysis of the W4A16 kernel.
//!
//! For a set of decode shapes this prints the full per-buffer traffic
//! decomposition, shows that the type-cast itself is never the bottleneck,
//! and quantifies how the workspace round trip caps the speedup — the
//! paper's counterintuitive headline finding.
//!
//! ```bash
//! cargo run --release --example bottleneck_analysis
//! ```

use ascend_w4a16::analysis::{report, traffic};
use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::util::stats;

fn main() -> anyhow::Result<()> {
    let machine = MachineConfig::ascend910();
    let sim = Simulator::new(machine.clone());

    // A fits-in-L2 shape, a spilling shape, and a K-dominant decode shape.
    let shapes = [
        ("deepseek mlp-down (fits L2)", 2048usize, 7168usize),
        ("glm ffn-down (spills L2)", 5120, 12288),
        ("deepseek kv-lora (K>>N)", 1536, 7168),
    ];
    const M: usize = 8;

    for (label, n, k) in shapes {
        let p = GemmProblem::new(M, n, k);
        println!("==================================================================");
        println!("{label}: M={M}, N={n}, K={k}");
        println!("==================================================================");
        let sk = sim.run(&kernels::schedule(&machine, &p, Strategy::SplitK)?)?;
        print!("{}", report::render_bottleneck(&machine, &sk));

        let fp16 = sim.run(&kernels::schedule(&machine, &p, Strategy::Fp16Native)?)?;
        let fused = sim.run(&kernels::schedule(&machine, &p, Strategy::Fused)?)?;
        let b = traffic::decompose(&sk);
        println!("\nstrategy comparison:");
        println!("  fp16 native                      : {}", stats::fmt_ns(fp16.total_ns));
        println!(
            "  w4a16 splitk (Algorithm 1)       : {}  ({:.2}x)",
            stats::fmt_ns(sk.total_ns),
            fp16.total_ns / sk.total_ns
        );
        println!(
            "  w4a16 fused (no round trip)      : {}  ({:.2}x)",
            stats::fmt_ns(fused.total_ns),
            fp16.total_ns / fused.total_ns
        );
        println!(
            "  round trip tax: {:.2}x -> {:.2}x of the theoretical 4x\n",
            fp16.total_ns / sk.total_ns,
            fp16.total_ns / fused.total_ns
        );
        let _ = b;
    }

    println!("paper §4.2 conclusion, reproduced: the bottleneck is not the \
              dequantization compute but the extra global-memory transfer of \
              the dequantized weights between the decoupled vector and cube \
              units; W4A16 therefore tops out near ~1.5x over FP16 instead \
              of the ~4x its storage reduction promises.");
    Ok(())
}
