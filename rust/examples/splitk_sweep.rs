//! Split-factor and tiling exploration on the simulator: sweep S and the
//! B-tile width for one GEMM shape and print the landscape the auto-tiler
//! navigates.
//!
//! ```bash
//! cargo run --release --example splitk_sweep -- --n 1024 --k 7680 --batch 8
//! ```

use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::kernels::{splitk, tiling, GemmProblem};
use ascend_w4a16::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 1024)?;
    let k = args.get_usize("k", 7680)?;
    let batch = args.get_usize("batch", 8)?;

    let machine = MachineConfig::ascend910();
    let sim = Simulator::new(machine.clone());
    let p = GemmProblem::new(batch, n, k);
    let auto = tiling::select_splitk(&machine, &p)?;
    println!(
        "auto tiling for M={batch}, N={n}, K={k}: bm={} bn={} bk={} S={}",
        auto.bm, auto.bn, auto.bk, auto.splits
    );

    println!("\n{:>5} {:>5} | {:>10} {:>8} {:>10}", "bn", "S", "time_us", "cores", "bound_by");
    for bn in [256usize, 128, 64] {
        if n % bn != 0 {
            continue;
        }
        for s in [1usize, 2, 4, 8, 16] {
            if k % s != 0 || (k / s) % p.group != 0 {
                continue;
            }
            let mut t = tiling::Tiling { bn, splits: s, ..auto };
            // shrink bk until the block fits L0
            while t.validate(&machine, &p).is_err() && t.bk > 16 {
                t.bk /= 2;
            }
            if t.validate(&machine, &p).is_err() {
                continue;
            }
            let trace = splitk::schedule(&machine, &p, &t)?;
            let r = sim.run(&trace)?;
            let cube_phase = r
                .phase_times
                .iter()
                .find(|pt| pt.name == "splitk_mmad")
                .unwrap();
            let marker = if bn == auto.bn && s == auto.splits { "  <- auto" } else { "" };
            println!(
                "{bn:>5} {s:>5} | {:>10.2} {:>8} {:>10}{marker}",
                r.total_ns / 1e3,
                cube_phase.active_engines,
                r.groups[0].bound_by,
            );
        }
    }
    println!("\nreading: more splits lift cube occupancy until the partial-buffer \
              traffic and reduce phase outweigh the gain; wider tiles cut \
              activation re-reads but can under-fill the grid.");
    Ok(())
}
