//! Golden-trace snapshot tests: canonical kernel schedules serialized to
//! committed JSON fixtures under `tests/fixtures/`, so schedule refactors
//! diff against known-good traces.
//!
//! Regenerate after an intentional schedule change with
//! `BLESS=1 cargo test --test golden_traces` and commit the diff.
//!
//! Every case pins its tiling explicitly (rather than going through the
//! heuristic tilers) so the fixtures are stable against tiler changes and
//! capture exactly the schedule construction.

use std::path::PathBuf;

use ascend_w4a16::analysis::{coschedule, golden, residency};
use ascend_w4a16::ascend::{KernelTrace, MachineConfig};
use ascend_w4a16::kernels::tiling::Tiling;
use ascend_w4a16::kernels::{chunked, data_parallel, splitk, w4a8, GemmProblem, ReduceMode};
use ascend_w4a16::model::llm::{layer_geometry, moe_geometry};
use ascend_w4a16::model::Precision;
use ascend_w4a16::util::json::Json;
use ascend_w4a16::workload::{DecodeLayer, DecodeStep, PrefillStep};

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures")).join(format!("{name}.json"))
}

fn bless_requested() -> bool {
    std::env::var("BLESS").map(|v| v == "1").unwrap_or(false)
}

/// Compare a trace's digest against its committed fixture (or regenerate
/// it under `BLESS=1`).
fn check(name: &str, trace: &KernelTrace) {
    check_json(name, golden::trace_to_json(trace));
}

/// Compare any golden digest against its committed fixture.
fn check_json(name: &str, got: Json) {
    let path = fixture_path(name);
    if bless_requested() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.to_string()).unwrap();
        eprintln!("blessed {}", path.display());
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        // Write the candidate so the diff is easy to inspect, then fail:
        // a missing fixture must be blessed and committed deliberately.
        let _ = std::fs::create_dir_all(path.parent().unwrap());
        let _ = std::fs::write(&path, got.to_string());
        panic!(
            "fixture {} was missing ({e}); wrote the current digest — \
             inspect and commit it (or run BLESS=1 to regenerate all)",
            path.display()
        );
    });
    let want = Json::parse(&text)
        .unwrap_or_else(|e| panic!("fixture {} is not valid JSON: {e}", path.display()));
    assert_eq!(
        got,
        want,
        "trace '{name}' diverges from its golden fixture {} — if the schedule \
         change is intentional, regenerate with BLESS=1 cargo test --test golden_traces",
        path.display()
    );
}

#[test]
fn splitk_decode_shape_matches_golden() {
    // The paper's acceptance decode shape (K >> N), tail-only reduce.
    let p = GemmProblem::new(8, 512, 16384);
    let t = Tiling { bm: 16, bn: 256, bk: 64, splits: 16, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = splitk::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("splitk_m8_n512_k16384_pipelined", &tr);
}

#[test]
fn splitk_streaming_reduce_matches_golden() {
    // 192 output tiles over 64 vector engines: the streamed reduce phases
    // (reduce_stream + reduce_tail) are part of the digest.
    let p = GemmProblem::new(16, 12288, 5120);
    let t = Tiling { bm: 16, bn: 64, bk: 128, splits: 2, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = splitk::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("splitk_m16_n12288_k5120_pipelined", &tr);
}

#[test]
fn splitk_barrier_reduce_matches_golden() {
    // Algorithm 1's barrier reduce on the acceptance shape (the C=1 /
    // barrier degeneration the pipelining must preserve).
    let p = GemmProblem::new(8, 512, 16384);
    let t = Tiling { bm: 16, bn: 256, bk: 64, splits: 16, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    let tr = splitk::schedule_reduce(&machine(), &p, &t, ReduceMode::Barrier).unwrap();
    check("splitk_m8_n512_k16384_barrier", &tr);
}

#[test]
fn chunked_spilling_shape_matches_golden() {
    // 120 MiB FP16 workspace: 4 chunks rotating through the pinned pair.
    let p = GemmProblem::new(8, 5120, 12288);
    let t = Tiling { bm: 16, bn: 256, bk: 64, splits: 4, chunks: 4, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = chunked::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("chunked_m8_n5120_k12288_pipelined", &tr);
}

#[test]
fn chunked_mid_shape_matches_golden() {
    let p = GemmProblem::new(8, 2048, 8192);
    let t = Tiling { bm: 16, bn: 128, bk: 128, splits: 2, chunks: 4, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = chunked::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("chunked_m8_n2048_k8192_pipelined", &tr);
}

#[test]
fn data_parallel_decode_shape_matches_golden() {
    let p = GemmProblem::new(8, 2048, 7168);
    let t = Tiling { bm: 16, bn: 256, bk: 64, splits: 1, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = data_parallel::schedule(&machine(), &p, &t).unwrap();
    check("dp_m8_n2048_k7168", &tr);
}

#[test]
fn moe_expert_batch_trace_matches_golden() {
    // One routed expert's down-projection at decode (m=1 token, N=7168,
    // K=2048 — DeepSeek-R1's expert shape): 224 output tiles over 64
    // vector engines exercise the UNEVEN floor-wave streaming gate, so
    // this fixture pins both the expert-batch schedule and the §11
    // generalized reduce stream.
    let p = GemmProblem::new(1, 7168, 2048);
    let t = Tiling { bm: 16, bn: 32, bk: 128, splits: 4, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = splitk::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("splitk_m1_n7168_k2048_pipelined", &tr);
}

#[test]
fn w4a8_dense_large_k_matches_golden() {
    // The W4A8 schedule on the dense large-K acceptance shape (DESIGN.md
    // §16) at 50% rebalance: mixed dequant/repack prologue, the INT8
    // activation-quantize wave, halved MMAD streams, and the
    // deferred-scale epilogue riding the trailing reduce group.
    let p = GemmProblem::new(8, 512, 16384).with_precision(Precision::W4A8);
    let t = Tiling { bm: 16, bn: 256, bk: 64, splits: 16, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 50 };
    t.validate(&machine(), &p).unwrap();
    let tr = w4a8::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("w4a8_m8_n512_k16384_pipelined", &tr);
}

#[test]
fn w4a8_moe_expert_batch_matches_golden() {
    // One routed expert's down-projection at W4A8 with every dequant
    // tile deferred (rebalance 100): the prologue is a pure INT4->INT8
    // repack and all scale application lands in `reduce_scale` behind
    // the streamed reduce.
    let p = GemmProblem::new(1, 7168, 2048).with_precision(Precision::W4A8);
    let t = Tiling { bm: 16, bn: 32, bk: 128, splits: 4, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 100 };
    t.validate(&machine(), &p).unwrap();
    let tr = w4a8::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("w4a8_m1_n7168_k2048_pipelined", &tr);
}

#[test]
fn merged_dense_pair_matches_golden() {
    // The co-scheduler's splice on a dense adjacent pair (DESIGN.md §12):
    // the K>>N acceptance shape's exposed barrier reduce moves into a
    // chunked consumer's chunk-0 dequant prologue — the fixture pins the
    // moved steps, the carried_partial re-classing and the preserved
    // chunk tag.
    let p = GemmProblem::new(8, 512, 16384);
    let pt = Tiling { bm: 16, bn: 256, bk: 64, splits: 16, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    pt.validate(&machine(), &p).unwrap();
    let prod = splitk::schedule_reduce(&machine(), &p, &pt, ReduceMode::Pipelined).unwrap();
    let c = GemmProblem::new(8, 2048, 8192);
    let ct = Tiling { bm: 16, bn: 128, bk: 128, splits: 2, chunks: 4, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    ct.validate(&machine(), &c).unwrap();
    let cons = chunked::schedule_reduce(&machine(), &c, &ct, ReduceMode::Pipelined).unwrap();
    let merged = coschedule::splice(&prod, &cons).expect("pair must be spliceable");
    check_json(
        "merged_splitk_m8_n512_k16384__chunked_m8_n2048_k8192",
        golden::merged_to_json(&merged),
    );
}

#[test]
fn merged_moe_expert_internal_pair_matches_golden() {
    // The MoE expert-batch internal pair: one expert instance's
    // reduce_tail spliced into the NEXT instance of the same schedule
    // (producer == consumer), streaming reduce preserved in the head.
    let p = GemmProblem::new(1, 7168, 2048);
    let t = Tiling { bm: 16, bn: 32, bk: 128, splits: 4, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = splitk::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    let merged = coschedule::splice(&tr, &tr).expect("internal pair must be spliceable");
    check_json(
        "merged_moe_expert_m1_n7168_k2048_internal",
        golden::merged_to_json(&merged),
    );
}

#[test]
fn resident_weight_trace_matches_golden() {
    // The residency planner's carried-weight re-class (DESIGN.md §13) on
    // the chunked mid shape: identical phase structure, with every
    // packed-weight and quant-param read re-classed carried_weight — the
    // fixture pins that byte conservation at digest level.
    let p = GemmProblem::new(8, 2048, 8192);
    let t = Tiling { bm: 16, bn: 128, bk: 128, splits: 2, chunks: 4, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&machine(), &p).unwrap();
    let tr = chunked::schedule_reduce(&machine(), &p, &t, ReduceMode::Pipelined).unwrap();
    check("chunked_m8_n2048_k8192_pipelined_resident", &residency::carry_weights(&tr));
}

#[test]
fn chain_splice_matches_golden() {
    // The chain-level co-scheduler (DESIGN.md §13): a barrier-reduce
    // producer whose 224 exposed tiles saturate the first consumer's
    // 32-step dequant prologue; the overflow lands in the second
    // prologue, both re-balanced least-loaded over the 64 vector engines.
    let m = machine();
    let p = GemmProblem::new(8, 7168, 2048);
    let pt = Tiling { bm: 16, bn: 32, bk: 128, splits: 4, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    pt.validate(&m, &p).unwrap();
    let prod = splitk::schedule_reduce(&m, &p, &pt, ReduceMode::Barrier).unwrap();
    let c = GemmProblem::new(8, 512, 2048);
    let ct = Tiling { bm: 16, bn: 256, bk: 128, splits: 2, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    ct.validate(&m, &c).unwrap();
    let cons = splitk::schedule_reduce(&m, &c, &ct, ReduceMode::Pipelined).unwrap();
    assert!(coschedule::saturates(&prod, &cons), "fixture premise: saturating tail");
    let merged = coschedule::splice_chain(m.total_vector_cores(), &prod, &cons, &cons)
        .expect("chain must be spliceable");
    check_json(
        "chain_splitk_m8_n7168_k2048__splitk_m8_n512_k2048x2",
        golden::merged_to_json(&merged),
    );
}

#[test]
fn dense_decode_step_graph_matches_golden() {
    // The full GLM-4.5 decode step at batch 8: attention + glue + the
    // four projection GEMMs, in issue order.
    let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
    let step = DecodeStep::new(layer, 2048, 40);
    check_json("decode_step_glm45_b8", golden::step_to_json(&step));
}

#[test]
fn moe_decode_step_graph_matches_golden() {
    // The full DeepSeek-MoE decode step at batch 8: routing + the 64
    // active-expert fan-out replacing the dense FFN pair.
    let layer = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
        .with_moe(moe_geometry("deepseek-moe").unwrap());
    let step = DecodeStep::new(layer, 2048, 56);
    check_json("decode_step_deepseek_moe_b8", golden::step_to_json(&step));
}

#[test]
fn dense_prefill_step_graph_matches_golden() {
    // A 512-token LLaMA-3.2 prefill chunk landing mid-prompt (kv_base
    // 1024): the digest pins the causal-context arithmetic (ctx =
    // m*kv_base + m(m+1)/2) and the attention passes it sizes.
    let geometry = layer_geometry("llama32").unwrap();
    let heads = PrefillStep::default_heads(&geometry);
    let step = PrefillStep::new(DecodeLayer::new(geometry, 512), 1024, heads);
    check_json("prefill_step_llama32_m512", golden::prefill_step_to_json(&step));
}

#[test]
fn moe_prefill_step_graph_matches_golden() {
    // A 256-token DeepSeek-MoE prefill chunk: top-8 routing saturates
    // all 256 experts at 8 tokens each — the large-M expert fan-out the
    // serve loop prices between decode ticks.
    let layer = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 256)
        .with_moe(moe_geometry("deepseek-moe").unwrap());
    let step = PrefillStep::new(layer, 512, 56);
    check_json("prefill_step_deepseek_moe_m256", golden::prefill_step_to_json(&step));
}
