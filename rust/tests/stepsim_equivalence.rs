//! Shim-equivalence harness for the `StepSim` migration (DESIGN.md §17):
//! every deprecated `simulate_step*` free function must produce a
//! bit-identical [`StepReport`] to the `StepSim` builder chain it
//! forwards to — over randomized dense AND MoE geometries, every
//! `OverlapMode` x `ResidencyMode` combination, heuristic and tuned
//! resolvers, decode and prefill graphs.
//!
//! "Bit-identical" is checked three ways at once: the JSON document
//! (Rust's `{}` f64 formatting is shortest-roundtrip, so string equality
//! is bit equality), the rendered table, and `to_bits` on the four
//! served totals plus the residency plan.  The deprecated entry points
//! are exercised deliberately — this file is their one sanctioned
//! caller for the deprecation PR.
#![allow(deprecated)]

use ascend_w4a16::analysis::layer::{self, forced_split_resolver, OverlapMode, Resolution, StepReport};
use ascend_w4a16::analysis::report::Report;
use ascend_w4a16::analysis::residency::ResidencyMode;
use ascend_w4a16::analysis::stepsim::StepSim;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::model::llm::{LayerGeometry, MoeGeometry};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{DecodeLayer, DecodeStep, PrefillStep};

type Assignment = (Strategy, kernels::tiling::Tiling, Resolution);

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

/// Random legal decoder-layer geometry, sometimes MoE (same draw as
/// `tests/coschedule.rs` / `tests/residency.rs`).
fn random_step(rng: &mut ascend_w4a16::util::prng::Rng) -> DecodeStep {
    let hidden = 128 * rng.usize_range(2, 24);
    let ffn = 128 * rng.usize_range(2, 32);
    let kv = 16 * rng.usize_range(1, hidden / 16);
    let geometry = LayerGeometry { hidden, ffn, kv, group: 128 };
    let batch = rng.usize_range(1, 64);
    let mut layer = DecodeLayer::new(geometry, batch);
    if rng.usize_range(0, 1) == 1 {
        let experts = *rng.choose(&[4usize, 8, 64]);
        let topk = (*rng.choose(&[1usize, 2])).min(experts);
        layer = layer.with_moe(MoeGeometry { experts, topk, expert_ffn: ffn });
    }
    let kv_len = 128 * rng.usize_range(1, 32);
    DecodeStep::new(layer, kv_len, DecodeStep::default_heads(&geometry))
}

/// Fixed fused-strategy resolver (exercises the non-split price path).
fn fused(m: &MachineConfig) -> impl FnMut(&GemmProblem) -> anyhow::Result<Assignment> + '_ {
    move |p| {
        Ok((
            Strategy::Fused,
            kernels::select_tiling(m, p, Strategy::Fused)?,
            Resolution::Heuristic,
        ))
    }
}

/// The bit-identity oracle: None if the reports agree, else a diff tag.
fn report_diff(old: &StepReport, new: &StepReport) -> Option<String> {
    if old.sequential_ns.to_bits() != new.sequential_ns.to_bits() {
        return Some(format!("sequential {} != {}", old.sequential_ns, new.sequential_ns));
    }
    if old.overlapped_ns.to_bits() != new.overlapped_ns.to_bits() {
        return Some(format!("overlapped {} != {}", old.overlapped_ns, new.overlapped_ns));
    }
    if old.exact_ns.to_bits() != new.exact_ns.to_bits() {
        return Some(format!("exact {} != {}", old.exact_ns, new.exact_ns));
    }
    if old.served_ns().to_bits() != new.served_ns().to_bits() {
        return Some(format!("served {} != {}", old.served_ns(), new.served_ns()));
    }
    match (&old.residency, &new.residency) {
        (None, None) => {}
        (Some(a), Some(b)) => {
            if a.resident_ns.to_bits() != b.resident_ns.to_bits()
                || a.baseline_ns.to_bits() != b.baseline_ns.to_bits()
                || a.pins != b.pins
                || a.pinned_bytes != b.pinned_bytes
            {
                return Some("residency plans differ".into());
            }
        }
        _ => return Some("residency plan presence differs".into()),
    }
    if old.to_json().to_string() != new.to_json().to_string() {
        return Some("json documents differ".into());
    }
    if old.render() != new.render() {
        return Some("rendered tables differ".into());
    }
    None
}

const OVERLAPS: [OverlapMode; 4] = [
    OverlapMode::Sequential,
    OverlapMode::Overlapped,
    OverlapMode::Exact,
    OverlapMode::Auto,
];
const RESIDENCIES: [ResidencyMode; 2] = [ResidencyMode::Off, ResidencyMode::Auto];

#[test]
fn simulate_step_with_matches_stepsim_on_random_geometries() {
    // The full grid — every overlap x residency combination, forced
    // splits (reduce tails everywhere, co-scheduler live) — on random
    // dense and MoE geometries.
    let m = machine();
    forall("shim == StepSim over the mode grid", 3, |rng| {
        let step = random_step(rng);
        if step.layer.validate().is_err() {
            return (false, format!("illegal geometry {:?}", step.layer.geometry));
        }
        for mode in OVERLAPS {
            for residency in RESIDENCIES {
                let old = match layer::simulate_step_with(
                    &m,
                    &step,
                    mode,
                    residency,
                    forced_split_resolver(&m),
                ) {
                    Ok(rep) => rep,
                    Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
                };
                let new = match StepSim::new(&m, &step)
                    .overlap(mode)
                    .residency(residency)
                    .resolver(forced_split_resolver(&m))
                    .run()
                {
                    Ok(rep) => rep,
                    Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
                };
                if let Some(diff) = report_diff(&old, &new) {
                    return (false, format!("{mode:?}/{residency:?}: {diff}"));
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn simulate_step_matches_stepsim_default_residency() {
    // `simulate_step` had no residency parameter; the builder's default
    // must reproduce it exactly (residency Off).
    let m = machine();
    forall("simulate_step == StepSim default", 4, |rng| {
        let step = random_step(rng);
        if step.layer.validate().is_err() {
            return (false, format!("illegal geometry {:?}", step.layer.geometry));
        }
        let mode = *rng.choose(&OVERLAPS);
        let old = match layer::simulate_step(&m, &step, mode, fused(&m)) {
            Ok(rep) => rep,
            Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
        };
        let new = match StepSim::new(&m, &step).overlap(mode).resolver(fused(&m)).run() {
            Ok(rep) => rep,
            Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
        };
        if old.residency.is_some() {
            return (false, "simulate_step must not plan residency".into());
        }
        match report_diff(&old, &new) {
            Some(diff) => (false, format!("{mode:?}: {diff}")),
            None => (true, String::new()),
        }
    });
}

#[test]
fn tuned_shims_match_stepsim_with_fresh_tuners() {
    // Two FRESH tuners, so both sides search the same cold cache and
    // every node resolves with the same `Resolution::Searched` provenance.
    let m = machine();
    let geom = ascend_w4a16::model::llm::layer_geometry("llama32").unwrap();
    let step = DecodeStep::new(DecodeLayer::new(geom, 8), 2048, DecodeStep::default_heads(&geom));
    for mode in OVERLAPS {
        let mut old_tuner = Tuner::new(m.clone());
        let old = layer::simulate_step_tuned(&m, &step, mode, &mut old_tuner).unwrap();
        let mut new_tuner = Tuner::new(m.clone());
        let new =
            StepSim::new(&m, &step).overlap(mode).tuner(&mut new_tuner).run().unwrap();
        assert_eq!(report_diff(&old, &new), None, "{mode:?}");
        assert_eq!(old_tuner.searches, new_tuner.searches, "{mode:?}: search counts differ");

        let mut old_tuner = Tuner::new(m.clone());
        let old =
            layer::simulate_step_tuned_with(&m, &step, mode, ResidencyMode::Auto, &mut old_tuner)
                .unwrap();
        let mut new_tuner = Tuner::new(m.clone());
        let new = StepSim::new(&m, &step)
            .overlap(mode)
            .residency(ResidencyMode::Auto)
            .tuner(&mut new_tuner)
            .run()
            .unwrap();
        assert_eq!(report_diff(&old, &new), None, "{mode:?} + residency");
    }
}

#[test]
fn prefill_shims_match_stepsim_prefill() {
    // The prefill graph walks the same op list: causal attention scores,
    // chunked projections, KV append — shim and builder must agree on
    // every mode combination, heuristic and tuned.
    let m = machine();
    let geom = ascend_w4a16::model::llm::layer_geometry("llama32").unwrap();
    let chunk = PrefillStep::new(DecodeLayer::new(geom, 256), 512, PrefillStep::default_heads(&geom));
    for mode in OVERLAPS {
        for residency in RESIDENCIES {
            let old = layer::simulate_prefill_step_with(
                &m,
                &chunk,
                mode,
                residency,
                forced_split_resolver(&m),
            )
            .unwrap();
            let new = StepSim::prefill(&m, &chunk)
                .overlap(mode)
                .residency(residency)
                .resolver(forced_split_resolver(&m))
                .run()
                .unwrap();
            assert_eq!(report_diff(&old, &new), None, "{mode:?}/{residency:?}");
        }
    }
    let mut old_tuner = Tuner::new(m.clone());
    let old = layer::simulate_prefill_step_tuned_with(
        &m,
        &chunk,
        OverlapMode::Auto,
        ResidencyMode::Auto,
        &mut old_tuner,
    )
    .unwrap();
    let mut new_tuner = Tuner::new(m.clone());
    let new = StepSim::prefill(&m, &chunk)
        .overlap(OverlapMode::Auto)
        .residency(ResidencyMode::Auto)
        .tuner(&mut new_tuner)
        .run()
        .unwrap();
    assert_eq!(report_diff(&old, &new), None, "tuned prefill");
    assert_eq!(old_tuner.searches, new_tuner.searches);
}
