//! Simulator integration tests: physical sanity of the timing model
//! (monotonicity, conservation, calibration anchors).

use ascend_w4a16::ascend::{
    BufferClass, ComputeOp, KernelTrace, MachineConfig, Phase, Simulator, TileStep, Unit,
    WorkspacePolicy,
};
use ascend_w4a16::util::proptest::forall;

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

fn phase(unit: Unit, engines: usize, steps: Vec<TileStep>) -> Phase {
    Phase {
        name: "t",
        unit,
        steps_per_engine: vec![steps; engines],
        pipelined_with_prev: false,
        chunk: None,
    }
}

fn trace(phases: Vec<Phase>, ws: u64, partial: u64) -> KernelTrace {
    KernelTrace {
        name: "t".into(),
        phases,
        workspace_bytes: ws,
        partial_bytes: partial,
        workspace_policy: WorkspacePolicy::Buffered,
    }
}

#[test]
fn time_monotone_in_bytes_property() {
    let sim = Simulator::new(machine());
    forall("more bytes, more time", 50, |rng| {
        let b1 = rng.usize_range(1_000, 1_000_000) as u64;
        let b2 = b1 + rng.usize_range(1, 1_000_000) as u64;
        let mk = |b: u64| {
            trace(
                vec![phase(
                    Unit::Cube,
                    8,
                    vec![TileStep::new(ComputeOp::Nop).read(BufferClass::WeightF16, b)],
                )],
                0,
                0,
            )
        };
        let t1 = sim.run(&mk(b1)).unwrap().total_ns;
        let t2 = sim.run(&mk(b2)).unwrap().total_ns;
        (t2 >= t1, format!("b1={b1} b2={b2} t1={t1} t2={t2}"))
    });
}

#[test]
fn time_monotone_in_compute_property() {
    let sim = Simulator::new(machine());
    forall("more macs, more time", 50, |rng| {
        let k1 = 16 * rng.usize_range(1, 64);
        let k2 = k1 + 16 * rng.usize_range(1, 64);
        let mk = |k: usize| {
            trace(
                vec![phase(
                    Unit::Cube,
                    4,
                    vec![TileStep::new(ComputeOp::Mmad { m: 16, n: 256, k })],
                )],
                0,
                0,
            )
        };
        let t1 = sim.run(&mk(k1)).unwrap().total_ns;
        let t2 = sim.run(&mk(k2)).unwrap().total_ns;
        (t2 >= t1, format!("k1={k1} k2={k2}"))
    });
}

#[test]
fn ledger_conserves_bytes_property() {
    let sim = Simulator::new(machine());
    forall("ledger conservation", 40, |rng| {
        // multiple of 8 so the per-engine division below is exact
        let ws_bytes = (rng.usize_range(1 << 10, 1 << 26) as u64 / 8) * 8;
        let t = trace(
            vec![
                phase(
                    Unit::Vector,
                    8,
                    vec![TileStep::new(ComputeOp::Nop).write(BufferClass::Workspace, ws_bytes / 8)],
                ),
                phase(
                    Unit::Cube,
                    8,
                    vec![TileStep::new(ComputeOp::Nop).read(BufferClass::Workspace, ws_bytes / 8)],
                ),
            ],
            ws_bytes,
            0,
        );
        let r = sim.run(&t).unwrap();
        let ws = r.ledger.class(BufferClass::Workspace);
        // reads: l2 + hbm must equal the bytes requested
        let read_total = ws.l2_read + ws.hbm_read;
        let ok = (read_total - ws_bytes as f64).abs() < 1.0
            && (ws.l2_write - ws_bytes as f64).abs() < 1.0;
        (ok, format!("ws={ws_bytes} read={read_total}"))
    });
}

#[test]
fn hbm_utilization_never_exceeds_one() {
    let sim = Simulator::new(machine());
    forall("hbm util <= 1", 40, |rng| {
        let bytes = rng.usize_range(1 << 16, 1 << 27) as u64;
        let engines = rng.usize_range(1, 32);
        let t = trace(
            vec![phase(
                Unit::Cube,
                engines,
                vec![TileStep::new(ComputeOp::Nop).read(BufferClass::WeightF16, bytes / engines as u64)],
            )],
            0,
            0,
        );
        let r = sim.run(&t).unwrap();
        let util = r.hbm_utilization(&machine());
        (util <= 1.0 + 1e-9, format!("util={util}"))
    });
}

#[test]
fn calibration_anchor_fp16_gemm_time() {
    // 2 * K * N bytes over 1.2 TB/s for (M=8, N=2048, K=7168) ~ 24.5 µs
    // of pure weight streaming; total with launch + fill must sit within
    // [24.5, 33] µs. This anchors Figure 3's baseline.
    use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
    let m = machine();
    let p = GemmProblem::new(8, 2048, 7168);
    let r = Simulator::new(m.clone())
        .run(&kernels::schedule(&m, &p, Strategy::Fp16Native).unwrap())
        .unwrap();
    let us = r.total_ns / 1e3;
    assert!((24.5..33.0).contains(&us), "fp16 native = {us} µs");
}

#[test]
fn empty_trace_rejected() {
    let sim = Simulator::new(machine());
    assert!(sim.run(&trace(vec![], 0, 0)).is_err());
}

#[test]
fn barrier_cost_scales_with_group_count() {
    let sim = Simulator::new(machine());
    let step = TileStep::new(ComputeOp::Nop).read(BufferClass::Activation, 1024);
    let two_groups = trace(
        vec![phase(Unit::Vector, 1, vec![step]), phase(Unit::Cube, 1, vec![step])],
        0,
        0,
    );
    let mut pipelined = two_groups.clone();
    pipelined.phases[1].pipelined_with_prev = true;
    let r2 = sim.run(&two_groups).unwrap();
    let r1 = sim.run(&pipelined).unwrap();
    assert_eq!(r2.barrier_ns, machine().barrier_ns);
    assert_eq!(r1.barrier_ns, 0.0);
}

#[test]
fn machine_validation_wired_into_cli_configs() {
    machine().validate().unwrap();
}
