//! Phase-level co-scheduler invariants (DESIGN.md §12): the splice
//! conserves work and never double-books an engine, the merged-trace
//! pricing never serves a slower plan than the sequential chain, and
//! `OverlapMode::Auto` never serves a slower plan than PR 3's first-order
//! ledger — on randomized geometries (dense and MoE) and across the
//! paper-model decode-step sweep.

use ascend_w4a16::analysis::coschedule;
use ascend_w4a16::analysis::layer::{self, forced_split_resolver, OverlapMode};
use ascend_w4a16::analysis::stepsim::StepSim;
use ascend_w4a16::ascend::{ComputeOp, MachineConfig, Simulator};
use ascend_w4a16::kernels::tiling::Tiling;
use ascend_w4a16::kernels::{self, splitk, GemmProblem, ReduceMode};
use ascend_w4a16::model::llm::{paper_layer_geometries, paper_moe_geometries, LayerGeometry, MoeGeometry};
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{DecodeLayer, DecodeStep};

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

/// A forced-split splitk trace for a random legal problem: every node
/// carries a reduce, so the producer side of the splice always exists.
fn forced_split_trace(m: &MachineConfig, p: &GemmProblem) -> ascend_w4a16::ascend::KernelTrace {
    let base = kernels::tiling::select_splitk(m, p).unwrap();
    let mut t = Tiling { splits: base.splits.max(2), ..base };
    if t.validate(m, p).is_err() {
        t = base;
    }
    splitk::schedule_reduce(m, p, &t, ReduceMode::Pipelined).unwrap()
}


#[test]
fn merged_trace_conserves_macs_and_reduce_steps_property() {
    let m = machine();
    forall("splice conserves work", 25, |rng| {
        let pn = 16 * rng.usize_range(1, 256);
        let pk = 128 * rng.usize_range(2, 64);
        let cn = 16 * rng.usize_range(1, 256);
        let ck = 128 * rng.usize_range(2, 64);
        let batch = rng.usize_range(1, 32);
        let prod = forced_split_trace(&m, &GemmProblem::new(batch, pn, pk));
        let cons = forced_split_trace(&m, &GemmProblem::new(batch, cn, ck));
        let Some(merged) = coschedule::splice(&prod, &cons) else {
            // A producer whose reduce streamed entirely has no exposed
            // tail; that is a legal non-spliceable draw.
            return (true, String::new());
        };
        let macs: u64 = merged.kernels.iter().map(|k| k.total_macs()).sum();
        if macs != prod.total_macs() + cons.total_macs() {
            return (false, format!("n={pn}/{cn}: MACs {macs} not conserved"));
        }
        let reduces: usize = merged.kernels.iter().map(|k| k.reduce_steps()).sum();
        if reduces != prod.reduce_steps() + cons.reduce_steps() {
            return (false, format!("n={pn}/{cn}: reduce steps {reduces} not conserved"));
        }
        // The merged trace still validates and simulates.
        match Simulator::new(m.clone()).run_merged(&merged) {
            Ok(r) if r.total_ns > 0.0 && r.total_ns.is_finite() => (true, String::new()),
            Ok(r) => (false, format!("degenerate merged time {}", r.total_ns)),
            Err(e) => (false, format!("n={pn}/{cn}: {e}")),
        }
    });
}

#[test]
fn spliced_phase_never_double_books_an_engine_property() {
    // Structural no-double-booking: after the splice, each vector engine
    // owns ONE serialized step sequence — the carried reduce steps (in
    // their original order) followed by its dequant steps (in theirs) —
    // and the engine list stays within the machine's vector cores.
    let m = machine();
    forall("no double booking", 25, |rng| {
        let pn = 16 * rng.usize_range(1, 256);
        let pk = 128 * rng.usize_range(2, 64);
        let cn = 16 * rng.usize_range(1, 256);
        let ck = 128 * rng.usize_range(2, 64);
        let batch = rng.usize_range(1, 32);
        let prod = forced_split_trace(&m, &GemmProblem::new(batch, pn, pk));
        let cons = forced_split_trace(&m, &GemmProblem::new(batch, cn, ck));
        let Some(merged) = coschedule::splice(&prod, &cons) else {
            return (true, String::new());
        };
        let spliced = &merged.kernels[1];
        let phase = &spliced.phases[0];
        if phase.steps_per_engine.len() > m.total_vector_cores() {
            return (false, format!("{} engines booked", phase.steps_per_engine.len()));
        }
        let tail = prod.exposed_reduce_range().unwrap();
        let moved: usize = prod.phases[tail].iter().map(|p| p.total_steps()).sum();
        if phase.total_steps() != cons.phases[0].total_steps() + moved {
            return (false, "spliced phase must carry every moved step exactly once".into());
        }
        for steps in &phase.steps_per_engine {
            let mut seen_dequant = false;
            for s in steps {
                match s.compute {
                    ComputeOp::Reduce { .. } if seen_dequant => {
                        return (false, "reduce step after dequant: ordering broken".into());
                    }
                    ComputeOp::Dequant { .. } => seen_dequant = true,
                    _ => {}
                }
            }
        }
        (true, String::new())
    });
}

/// Random legal decoder-layer geometry, sometimes MoE (mirrors
/// `tests/properties.rs`).
fn random_step(rng: &mut ascend_w4a16::util::prng::Rng) -> DecodeStep {
    let hidden = 128 * rng.usize_range(2, 24);
    let ffn = 128 * rng.usize_range(2, 32);
    let kv = 16 * rng.usize_range(1, hidden / 16);
    let geometry = LayerGeometry { hidden, ffn, kv, group: 128 };
    let batch = rng.usize_range(1, 64);
    let mut layer = DecodeLayer::new(geometry, batch);
    if rng.usize_range(0, 1) == 1 {
        let experts = *rng.choose(&[4usize, 8, 64]);
        let topk = (*rng.choose(&[1usize, 2])).min(experts);
        layer = layer.with_moe(MoeGeometry { experts, topk, expert_ffn: ffn });
    }
    let kv_len = 128 * rng.usize_range(1, 32);
    DecodeStep::new(layer, kv_len, DecodeStep::default_heads(&geometry))
}

#[test]
fn exact_never_slower_than_sequential_on_random_geometries() {
    // The co-scheduler declines every merge that prices slower, so
    // `Exact <= Sequential` holds on ANY geometry — dense and MoE.
    let m = machine();
    forall("exact <= sequential", 10, |rng| {
        let step = random_step(rng);
        if step.layer.validate().is_err() {
            return (false, format!("illegal geometry {:?}", step.layer.geometry));
        }
        let rep = match StepSim::new(&m, &step)
            .overlap(OverlapMode::Exact)
            .resolver(forced_split_resolver(&m))
            .run()
        {
            Ok(rep) => rep,
            Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
        };
        if rep.served_ns() != rep.exact_ns {
            return (false, "Exact mode must serve exact_ns".into());
        }
        (
            rep.exact_ns <= rep.sequential_ns * 1.000001,
            format!("exact {} > sequential {}", rep.exact_ns, rep.sequential_ns),
        )
    });
}

#[test]
fn auto_never_slower_than_ledger_on_random_geometries() {
    // Acceptance: `Auto` (min of sequential, ledger, exact) never serves
    // a slower plan than PR 3's first-order ledger.
    let m = machine();
    forall("auto <= ledger", 10, |rng| {
        let step = random_step(rng);
        if step.layer.validate().is_err() {
            return (false, format!("illegal geometry {:?}", step.layer.geometry));
        }
        let auto = match StepSim::new(&m, &step)
            .overlap(OverlapMode::Auto)
            .resolver(forced_split_resolver(&m))
            .run()
        {
            Ok(rep) => rep,
            Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
        };
        let ledger = match StepSim::new(&m, &step)
            .overlap(OverlapMode::Overlapped)
            .resolver(forced_split_resolver(&m))
            .run()
        {
            Ok(rep) => rep,
            Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
        };
        (
            auto.served_ns() <= ledger.served_ns() * 1.000001,
            format!("auto {} > ledger {}", auto.served_ns(), ledger.served_ns()),
        )
    });
}

#[test]
fn exact_beats_ledger_on_resident_partial_pair() {
    // Deterministic pinned pair: the producer's partials are L2-resident,
    // so the merged trace recovers the whole exposed tail group PLUS the
    // barrier in front of it — strictly more than the first-order
    // `min(reduce, slack)` term can claim.
    let m = machine();
    let sim = Simulator::new(m.clone());
    let p = GemmProblem::new(8, 512, 16384);
    let t = Tiling { bm: 16, bn: 256, bk: 64, splits: 16, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    t.validate(&m, &p).unwrap();
    let prod = splitk::schedule_reduce(&m, &p, &t, ReduceMode::Pipelined).unwrap();
    let c = GemmProblem::new(8, 2048, 8192);
    let ct = Tiling { bm: 16, bn: 128, bk: 128, splits: 2, chunks: 1, dequant_bk: 128, dequant_bn: 256, rebalance: 0 };
    ct.validate(&m, &c).unwrap();
    let cons = splitk::schedule_reduce(&m, &c, &ct, ReduceMode::Pipelined).unwrap();
    let prod_rep = sim.run(&prod).unwrap();
    let seq = prod_rep.total_ns + sim.run(&cons).unwrap().total_ns;
    let d = coschedule::pair_decision(&sim, &prod, &cons, seq).unwrap().unwrap();
    assert!(d.merged_applied(), "resident pair must merge: {d:?}");
    // The producer's partials fit L2 alongside its workspace.
    assert_eq!(prod_rep.l2_model.partial_hit, 1.0, "test premise: resident partials");
    // First-order term for the same pair: the ledger can claim at most
    // the exposed tail group's time.  With resident partials the merged
    // trace recovers that whole group plus the barrier fronting it.
    let tail_ns = prod_rep.groups.last().unwrap().total_ns;
    assert!(
        d.gain_ns > tail_ns * 0.999,
        "exact gain {} should recover at least the tail group {} (plus its barrier)",
        d.gain_ns,
        tail_ns
    );
}

#[test]
fn paper_sweep_exact_never_slower_than_ledger_and_strictly_faster_somewhere() {
    // Acceptance criteria on the paper-model decode-step sweep (tuned
    // strategies, like the e2e_layer bench): Exact <= Overlapped on every
    // model/batch, and at least one K>N adjacent pair where the merged
    // trace strictly beats the first-order term.
    //
    // Why the tuned half holds: tuned winners mostly have no exposed
    // reduce (the fused ablation wins most shapes and carries no dequant
    // prologue either), so most steps have an empty ledger and the two
    // prices coincide; the pairs that do exist are small-N nodes whose
    // split partials are L2-resident, where the merged trace recovers
    // the whole tail group plus its barrier — at least the ledger's
    // min(tail, slack) term.  If a future tuner change lands in the
    // spilled-carried-partial regime where the exact simulation prices
    // BELOW the (over-optimistic) first-order estimate, this assert is
    // the alarm that the ledger's estimate needs the §12 contention
    // terms, not a bug in the co-scheduler.
    let m = machine();
    let mut tuner = ascend_w4a16::tune::Tuner::new(m.clone());
    let mut steps: Vec<(String, DecodeStep)> = Vec::new();
    for (model, geom) in paper_layer_geometries() {
        for batch in [1usize, 8, 64] {
            let layer = DecodeLayer::new(geom, batch);
            steps.push((
                format!("{model} b={batch}"),
                DecodeStep::new(layer, 2048, DecodeStep::default_heads(&geom)),
            ));
        }
    }
    for (model, geom, moe) in paper_moe_geometries() {
        for batch in [1usize, 8, 64] {
            let layer = DecodeLayer::new(geom, batch).with_moe(moe);
            steps.push((
                format!("{model} b={batch}"),
                DecodeStep::new(layer, 2048, DecodeStep::default_heads(&geom)),
            ));
        }
    }
    for (tag, step) in &steps {
        let rep = StepSim::new(&m, step)
            .overlap(OverlapMode::Auto)
            .tuner(&mut tuner)
            .run()
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
        assert!(
            rep.exact_ns <= rep.overlapped_ns * 1.000001,
            "{tag}: exact {} slower than ledger {}",
            rep.exact_ns,
            rep.overlapped_ns
        );
        assert!(rep.served_ns() <= rep.sequential_ns * 1.000001, "{tag}");
    }
    // The strict win: forced splits on the MoE step guarantee exposed
    // reduce tails on the K>N expert GEMMs (the tuned sweep above may
    // legitimately pick S=1 nodes with nothing to overlap).
    let (_, geom, moe) = paper_moe_geometries().into_iter().next().expect("a MoE preset");
    let step = DecodeStep::new(DecodeLayer::new(geom, 8).with_moe(moe), 2048, 56);
    let rep = StepSim::new(&m, &step)
        .overlap(OverlapMode::Exact)
        .resolver(forced_split_resolver(&m))
        .run()
        .unwrap();
    let strict = rep.ledger.iter().any(|pair| {
        let producer_k_dominant = match &rep.nodes[pair.producer] {
            layer::StepNodeReport::Gemm(g) => g.problem.k > g.problem.n,
            layer::StepNodeReport::Vector(_) => false,
        };
        producer_k_dominant
            && pair.exact.map(|d| d.gain_ns).unwrap_or(0.0) > pair.gain_ns + 1e-6
    });
    assert!(
        strict,
        "no K>N adjacent pair where the merged trace strictly beats the ledger: {:?}",
        rep.ledger
    );
}
