//! Deterministic chaos harness (DESIGN.md §14): the fault-tolerant
//! serving loop under seeded stragglers, transient step failures,
//! bounded-queue shedding and per-request deadlines — all on synthetic
//! (config-only) manifests, so no artifacts or PJRT are needed.
//!
//! The invariants:
//! * the server never panics and `drain` never errors under chaos;
//! * outcome conservation — every offered request ends in exactly one of
//!   {completed, shed, expired, failed};
//! * determinism — the same seeds reproduce the same results, and every
//!   COMPLETED request's tokens are bit-identical to the fault-free run;
//! * the degradation ladder prices each rung no faster than the rung
//!   below it (resident <= overlapped <= layer <= splitk default).

use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::coordinator::{
    member_tail_penalty_us, Admission, BatchPolicy, Batcher, DecodeRequest, DecodeResult,
    FaultKind, FaultPlan, Outcome, RouteRung, Router, ServeOptions, Server, ADMISSION_FAULT_NAME,
    CACHE_WRITE_FAULT_NAME,
};
use ascend_w4a16::runtime::artifacts::DecodeConfig;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{Arrival, ArrivalPlan, DecodeLayer, RequestGenerator};

/// Three config-only decode artifacts (batch 1/2/4) — the router builds
/// synthetic engines, so the whole coordinator stack runs end to end.
fn manifest_json() -> String {
    manifest_json_with_group(128)
}

/// Like [`manifest_json`], with a chosen dequant group size.  A group
/// that divides neither `hidden` nor `ffn` makes every GEMM node
/// structurally unpriceable, so routing serves *unpriced* and every tick
/// costs `ServerConfig::default_step_us` — the lever the sub-µs
/// straggler regression pulls.
fn manifest_json_with_group(group: usize) -> String {
    let artifact = |batch: usize| {
        format!(
            r#"    {{
      "name": "decode_tiny_b{batch}",
      "kind": "decode",
      "path": "decode_tiny_b{batch}.hlo.txt",
      "model": "tiny",
      "batch": {batch},
      "config": {{"vocab": 512, "hidden": 256, "layers": 2, "heads": 4,
                 "ffn": 1024, "max_seq": 64, "group": {group}, "params": 0}},
      "inputs": [],
      "outputs": []
    }}"#
        )
    };
    format!(
        "{{\n  \"group\": {group},\n  \"batch_sizes\": [1, 2, 4],\n  \"paper_shapes\": [],\n  \"artifacts\": [\n{},\n{},\n{}\n  ]\n}}",
        artifact(1),
        artifact(2),
        artifact(4)
    )
}

fn decode_config() -> DecodeConfig {
    DecodeConfig {
        vocab: 512,
        hidden: 256,
        layers: 2,
        heads: 4,
        ffn: 1024,
        max_seq: 64,
        group: 128,
        params: 0,
        moe_experts: 0,
        moe_topk: 0,
    }
}

/// Write the manifest plus a fully warmed tune cache (shape winners,
/// pair decisions, residency plans for every compiled batch), so routing
/// serves the `full` rung and the tests run cache-only.
fn chaos_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
    let mut tuner = Tuner::new(MachineConfig::ascend910());
    for batch in [1usize, 2, 4] {
        let layer = DecodeLayer::from_decode_config(&decode_config(), batch);
        for node in layer.gemm_nodes() {
            tuner.resolve(&node.problem).unwrap();
        }
        for pair in layer.overlap_pairs() {
            tuner.resolve_overlap(&pair.producer, &pair.consumer).unwrap();
        }
        tuner.resolve_residency(&layer).unwrap();
    }
    tuner.save_to(dir.join("tune_cache.json")).unwrap();
    dir
}

fn build_server<'rt>(
    rt: &'rt Runtime,
    dir: &std::path::Path,
    queue_cap: usize,
    faults: Option<FaultPlan>,
) -> Server<'rt> {
    let mf = Manifest::load(dir).unwrap();
    let router = Router::new(rt, mf, "tiny").unwrap();
    let sizes = router.batch_sizes();
    let policy = BatchPolicy::new(sizes).unwrap().with_queue_cap(queue_cap);
    let mut server = Server::new(router, Batcher::new(policy));
    server.set_faults(faults);
    server
}

/// Submit a seeded burst (optionally deadlined) and drain; returns the
/// results, the shed count, and the server for metric inspection.
fn run_burst<'rt>(
    rt: &'rt Runtime,
    dir: &std::path::Path,
    n: usize,
    req_seed: u64,
    queue_cap: usize,
    deadline_us: Option<u64>,
    faults: Option<FaultPlan>,
) -> (Vec<DecodeResult>, usize, Server<'rt>) {
    let mut server = build_server(rt, dir, queue_cap, faults);
    let mut generator = RequestGenerator::new(req_seed, 512, 64);
    let mut shed = 0usize;
    for mut req in generator.burst(n) {
        if let Some(d) = deadline_us {
            req = req.with_deadline_us(d);
        }
        if let Admission::Shed { retry_after_us } = server.submit(req) {
            assert!(retry_after_us > 0, "shed must carry a retry hint");
            shed += 1;
        }
    }
    let results = server.drain().expect("drain never errors under chaos");
    (results, shed, server)
}

#[test]
fn acceptance_64_request_drain_under_10pct_faults() {
    // The PR's headline acceptance: 10% step fault rate, bounded queue,
    // 64-request drain — zero panics, every request accounted.
    let dir = chaos_dir("accept");
    let rt = Runtime::cpu().unwrap();
    let (results, shed, server) =
        run_burst(&rt, &dir, 64, 7, 32, None, Some(FaultPlan::new(0xC0FFEE, 0.10)));
    assert_eq!(shed, 32, "a 32-cap queue sheds the second half of the burst");
    assert_eq!(results.len() + shed, 64, "every offered request is accounted");
    let snap = server.metrics.snapshot();
    assert!(snap.outcomes_accounted(), "conservation violated");
    assert_eq!(snap.requests_admitted, 64);
    assert_eq!(snap.requests_shed, 32);
    assert!(
        snap.requests_completed > 0,
        "a 10% fault rate with retries must still complete work: {snap:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fault_free_run_is_deterministic_and_chaos_completions_match_it() {
    let dir = chaos_dir("det");
    let rt = Runtime::cpu().unwrap();
    let (baseline, _, _) = run_burst(&rt, &dir, 24, 11, 1024, None, None);
    let (again, _, _) = run_burst(&rt, &dir, 24, 11, 1024, None, None);
    assert_eq!(baseline.len(), 24);
    assert!(baseline.iter().all(|r| r.outcome == Outcome::Completed));
    for (a, b) in baseline.iter().zip(&again) {
        assert_eq!((a.id, &a.tokens), (b.id, &b.tokens), "fault-free serving must replay");
    }

    // Under seeded chaos, whatever COMPLETES is bit-identical to the
    // fault-free run: stragglers land late but correct, retried steps
    // re-execute the same deterministic step, and failures never corrupt
    // surviving groupmates.
    for fault_seed in [1u64, 0xDEAD, 42] {
        let (chaos, _, server) = run_burst(
            &rt,
            &dir,
            24,
            11,
            1024,
            None,
            Some(FaultPlan::new(fault_seed, 0.25)),
        );
        assert_eq!(chaos.len(), 24);
        assert!(server.metrics.snapshot().outcomes_accounted());
        for r in chaos.iter().filter(|r| r.outcome == Outcome::Completed) {
            let base = baseline.iter().find(|b| b.id == r.id).unwrap();
            assert_eq!(
                r.tokens, base.tokens,
                "seed {fault_seed}: completed request {} diverged from the fault-free run",
                r.id
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chaos_property_outcomes_conserve_and_never_panic() {
    let dir = chaos_dir("prop");
    let rt = Runtime::cpu().unwrap();
    forall("chaos conservation", 12, |rng| {
        let n = rng.usize_range(1, 40);
        let rate = rng.f64() * 0.6;
        let fault_seed = rng.next_u64();
        let queue_cap = rng.usize_range(1, 48);
        let deadline_us =
            if rng.f64() < 0.4 { Some(rng.usize_range(1, 60_000) as u64) } else { None };
        let (results, shed, server) = run_burst(
            &rt,
            &dir,
            n,
            rng.next_u64(),
            queue_cap,
            deadline_us,
            Some(FaultPlan::new(fault_seed, rate)),
        );
        let snap = server.metrics.snapshot();
        if !snap.outcomes_accounted() {
            return (
                false,
                format!(
                    "admitted {} != {} + {} + {} + {}",
                    snap.requests_admitted,
                    snap.requests_completed,
                    snap.requests_shed,
                    snap.requests_expired,
                    snap.requests_failed
                ),
            );
        }
        if results.len() + shed != n {
            return (false, format!("{} results + {shed} shed != {n} offered", results.len()));
        }
        for r in &results {
            match r.outcome {
                Outcome::Completed => {
                    if r.tokens.is_empty() {
                        return (false, format!("completed {} with no tokens", r.id));
                    }
                    if r.error.is_some() {
                        return (false, format!("completed {} carries an error", r.id));
                    }
                }
                Outcome::Failed => {
                    if r.error.is_none() {
                        return (false, format!("failed {} without a cause", r.id));
                    }
                }
                Outcome::Expired => {}
            }
        }
        (true, String::new())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn expired_queue_requests_take_no_steps() {
    let dir = chaos_dir("expire");
    let rt = Runtime::cpu().unwrap();
    let mut server = build_server(&rt, &dir, 1024, None);
    server.submit(DecodeRequest::new(1, vec![3, 4], 8).with_deadline_us(5));
    server.advance_clock(6); // the deadline passes while queued
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].outcome, Outcome::Expired);
    assert!(results[0].tokens.is_empty(), "expired in queue: no engine work");
    assert_eq!(results[0].steps, 0);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests_expired, 1);
    assert_eq!(snap.groups_formed, 0, "an expired request must not occupy a group");
    assert!(snap.outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mid_flight_deadline_keeps_partial_tokens_and_frees_the_group() {
    // One deadlined member expires mid-decode (partial generation kept);
    // its groupmate still completes its full budget.
    let dir = chaos_dir("midflight");
    let rt = Runtime::cpu().unwrap();

    // Baseline: both complete (no deadlines).
    let mut server = build_server(&rt, &dir, 1024, None);
    server.submit(DecodeRequest::new(1, vec![9], 10));
    server.submit(DecodeRequest::new(2, vec![8], 10));
    let baseline = server.drain().unwrap();
    let base1 = baseline.iter().find(|r| r.id == 1).unwrap().tokens.clone();
    assert_eq!(base1.len(), 10);

    // What one step costs on the virtual clock for this batch-2 group.
    let mut server = build_server(&rt, &dir, 1024, None);
    let step_us = {
        let plan = server.router.layer_plan(2).unwrap();
        ((plan.predicted_served_ns().unwrap() / 1_000.0).ceil() as u64).max(1)
    };
    // Expires strictly between step 2 and the 10-step budget.
    server.submit(DecodeRequest::new(1, vec![9], 10).with_deadline_us(2 * step_us));
    server.submit(DecodeRequest::new(2, vec![8], 10));
    let results = server.drain().unwrap();
    let r1 = results.iter().find(|r| r.id == 1).unwrap();
    let r2 = results.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(r1.outcome, Outcome::Expired);
    assert!(
        !r1.tokens.is_empty() && r1.tokens.len() < 10,
        "partial generation expected, got {} tokens",
        r1.tokens.len()
    );
    assert_eq!(r1.tokens[..], base1[..r1.tokens.len()], "partial must prefix the baseline");
    assert_eq!(r2.outcome, Outcome::Completed);
    assert_eq!(r2.tokens.len(), 10, "groupmate must not be dragged down by the expiry");
    assert!(server.metrics.snapshot().outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_step_fault_retries_then_completes_identically() {
    // Pick a fault seed whose plan fails the first attempt of group 0's
    // step 0 but passes some retry of every early step — the request must
    // complete bit-identically, with the retry and fault counted.
    let dir = chaos_dir("retry");
    let rt = Runtime::cpu().unwrap();
    let (baseline, _, _) = run_burst(&rt, &dir, 1, 5, 1024, None, None);
    assert_eq!(baseline[0].outcome, Outcome::Completed);

    let rate = 0.08;
    let plan = (0u64..)
        .map(|seed| FaultPlan::new(seed, rate))
        .find(|p| {
            let first = matches!(
                p.step_fault(0, 0, 0),
                Some(FaultKind::EngineFault) | Some(FaultKind::ClientError)
            );
            // Every step of the only group must survive within 4 attempts.
            let survivable =
                (0..64u64).all(|s| (0..4u32).any(|a| p.step_fault(0, s, a).is_none()));
            first && survivable
        })
        .unwrap();
    let (results, _, server) = run_burst(&rt, &dir, 1, 5, 1024, None, Some(plan));
    assert_eq!(results[0].outcome, Outcome::Completed);
    assert_eq!(results[0].tokens, baseline[0].tokens, "retried steps must replay exactly");
    let snap = server.metrics.snapshot();
    assert!(snap.retries >= 1, "the injected failure must surface as a retry: {snap:?}");
    assert!(!snap.faults.is_empty());
    assert!(snap.outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn exhausted_retries_fail_members_not_the_server() {
    // A fault plan whose group-0 step-0 draws a transient error on every
    // attempt: the retry budget exhausts, the member ends Failed (typed,
    // with a cause) — and the server keeps serving.
    let dir = chaos_dir("exhaust");
    let rt = Runtime::cpu().unwrap();
    let lethal = (0u64..)
        .map(|seed| FaultPlan::new(seed, 1.0))
        .find(|p| {
            (0..4u32).all(|a| {
                matches!(
                    p.step_fault(0, 0, a),
                    Some(FaultKind::EngineFault) | Some(FaultKind::ClientError)
                )
            })
        })
        .unwrap();
    let mut server = build_server(&rt, &dir, 1024, Some(lethal));
    server.submit(DecodeRequest::new(1, vec![3], 4));
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].outcome, Outcome::Failed);
    assert!(results[0].error.as_deref().unwrap().contains("attempts"));
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests_failed, 1);
    assert!(snap.retries >= 1);
    assert!(snap.outcomes_accounted());

    // Disarm faults: the SAME server immediately serves again.
    server.set_faults(None);
    server.submit(DecodeRequest::new(2, vec![3], 4));
    let results = server.drain().unwrap();
    assert_eq!(results[0].outcome, Outcome::Completed);
    assert!(server.metrics.snapshot().outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn admission_faults_shed_typed_and_close_conservation() {
    // Rate 1.0: every serve-path admission faults, so the whole plan is
    // shed under the `admission_fault` reason — no request ever holds a
    // slot or a KV page, and the conservation ledger still closes.
    let dir = chaos_dir("admit-fault");
    let rt = Runtime::cpu().unwrap();
    let mut server = build_server(&rt, &dir, 1024, Some(FaultPlan::new(9, 1.0)));
    let plan = ArrivalPlan::poisson(3, 10.0, 6, 64);
    let opts = ServeOptions::new(4, 4).with_queue_cap(1024);
    let report = server.serve_load(&plan, &opts).unwrap();
    assert!(report.results.is_empty(), "shed requests never reach a slot");
    assert!(report.kv_idle);
    assert_eq!(report.kv_peak_pages, 0, "no admission, no pages");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests_admitted, 6);
    assert_eq!(snap.requests_shed, 6);
    assert_eq!(snap.shed_reasons.get(ADMISSION_FAULT_NAME), Some(&6));
    assert_eq!(snap.faults.get(ADMISSION_FAULT_NAME), Some(&6));
    assert!(snap.outcomes_accounted());
    assert!(snap.sheds_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cache_write_fault_fails_the_request_with_partial_tokens() {
    // Find a plan that admits request 0, survives every decode tick
    // within the retry budget, but draws a KV-cache write fault before
    // the 8-token budget completes.  Cache-write faults are not
    // retryable — the request must end Failed with exactly the tokens
    // generated before the lost write, typed in the fault ledger.
    let dir = chaos_dir("cache-fault");
    let rt = Runtime::cpu().unwrap();
    let rate = 0.5;
    let (plan, first_fault) = (0u64..)
        .map(|seed| FaultPlan::new(seed, rate))
        .find_map(|p| {
            if p.admission_fault(0) {
                return None;
            }
            let first = (0..8u64).find(|&t| p.cache_write_fault(0, t))?;
            let survivable =
                (0..32u64).all(|s| (0..4u32).any(|a| p.step_fault(0, s, a).is_none()));
            survivable.then_some((p, first))
        })
        .unwrap();
    let mut server = build_server(&rt, &dir, 1024, Some(plan));
    let arrivals = ArrivalPlan {
        arrivals: vec![Arrival { at_us: 0, prompt_len: 4, max_new_tokens: 8 }],
    };
    let opts = ServeOptions::new(1, 4).with_queue_cap(8);
    let report = server.serve_load(&arrivals, &opts).unwrap();
    assert!(report.kv_idle, "the failed slot must release its pages");
    assert_eq!(report.results.len(), 1);
    let r = &report.results[0];
    assert_eq!(r.outcome, Outcome::Failed);
    assert!(
        r.error.as_deref().unwrap().contains("cache write fault"),
        "typed cause expected: {:?}",
        r.error
    );
    assert_eq!(
        r.tokens.len() as u64,
        first_fault,
        "generation must stop at the lost write"
    );
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests_failed, 1);
    assert!(snap.faults.get(CACHE_WRITE_FAULT_NAME).copied().unwrap_or(0) >= 1);
    assert!(snap.outcomes_accounted());
    assert!(snap.sheds_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sub_microsecond_straggler_steps_charge_positive_penalty() {
    // Regression for the penalty-truncation bug: the straggler charge
    // `step_us * (mult_x100 - 100) / 100` used flooring division, so a
    // 1µs decode tick with a 1.5x straggler (mult_x100 = 150) injected
    // ZERO penalty — chaos runs counted stragglers whose latency never
    // reached the clock.  The fix rounds up with a >= 1µs floor, so the
    // total penalty is at least one µs per injected straggler.
    //
    // Group 192 divides neither hidden (256) nor ffn (1024), so every
    // GEMM node is structurally unpriceable, the route serves unpriced,
    // and each tick costs `default_step_us` — pinned here to 1µs.
    let dir = std::env::temp_dir()
        .join(format!("w4a16-chaos-subus-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json_with_group(192)).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(
        router.route(2).plan.as_ref().and_then(|p| p.predicted_served_ns()).is_none(),
        "premise: the route must be unpriced so ticks cost default_step_us"
    );
    let sizes = router.batch_sizes();
    let mut server =
        Server::new(router, Batcher::new(BatchPolicy::new(sizes).unwrap().with_queue_cap(64)));
    server.config.default_step_us = 1;
    // A plan that injects at least one straggler at attempt 0 of an early
    // decode tick of serve session 0, and lets every early tick land
    // within the retry budget (so the run keeps decoding past it).
    let plan = (0u64..)
        .map(|seed| FaultPlan::new(seed, 0.4))
        .find(|p| {
            let straggles = (0..16u64)
                .any(|t| matches!(p.step_fault(0, t, 0), Some(FaultKind::Straggler { .. })));
            let survivable =
                (0..64u64).all(|s| (0..4u32).any(|a| p.step_fault(0, s, a).is_none()));
            straggles && survivable
        })
        .unwrap();
    server.set_faults(Some(plan));
    let arrivals = ArrivalPlan {
        arrivals: (0..4)
            .map(|i| Arrival { at_us: i, prompt_len: 4, max_new_tokens: 24 })
            .collect(),
    };
    let opts = ServeOptions::new(2, 4).with_queue_cap(64);
    server.serve_load(&arrivals, &opts).unwrap();
    let snap = server.metrics.snapshot();
    let stragglers = snap.faults.get("straggler").copied().unwrap_or(0);
    assert!(stragglers > 0, "the seed search guarantees an injected straggler: {snap:?}");
    assert!(
        snap.straggler_penalty_us >= stragglers,
        "every injected straggler must charge >= 1µs: {} stragglers, {} µs total",
        stragglers,
        snap.straggler_penalty_us
    );
    assert!(snap.outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn member_faults_bill_the_slot_tail_not_the_whole_step() {
    // Satellite regression (DESIGN.md §18): a straggling batch MEMBER
    // serializes only its own slot's share of the step tail —
    // `ceil(step/batch)` scaled by the multiplier excess — never the
    // whole step.  Half (a) pins the shared charge helper against the
    // whole-step straggler charge (its `batch = 1` degenerate case)
    // across the full multiplier grid; half (b) replays the fault chain
    // through a real serve run and checks the billed penalty equals the
    // slot-tail charge exactly, strictly below the whole-step cost.
    for mult in (150u32..=700).step_by(50) {
        for batch in [2usize, 4, 8] {
            for step in [1u64, 3, 72, 1_000, 9_931] {
                let member = member_tail_penalty_us(step, batch, mult);
                let whole = member_tail_penalty_us(step, 1, mult);
                assert!(member >= 1, "1µs floor: step {step} batch {batch} mult {mult}");
                assert!(
                    member <= whole,
                    "member tail must never exceed the whole step: \
                     step {step} batch {batch} mult {mult}: {member} > {whole}"
                );
                if step >= 2 * batch as u64 {
                    assert!(
                        member < whole,
                        "member tail must be STRICTLY cheaper once the step \
                         amortizes over the batch: step {step} batch {batch} \
                         mult {mult}: {member} >= {whole}"
                    );
                }
            }
        }
    }

    // (b) End to end.  Group 192 makes the route unpriced, so every
    // decode tick costs `default_step_us` — pinned to 1000µs for
    // headroom.  Seed-search a plan whose ONLY fault in the live window
    // is a single member fault: no admission faults for the two
    // requests, no whole-step faults at attempt 0 of any tick (so no
    // retries and no whole-step straggler charges mix into the
    // penalty), no cache-write faults.  The billed penalty is then
    // exactly one slot-tail charge at the chain's multiplier.
    let dir = std::env::temp_dir().join(format!("w4a16-chaos-member-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json_with_group(192)).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let router = Router::new(&rt, mf, "tiny").unwrap();
    let sizes = router.batch_sizes();
    let mut server =
        Server::new(router, Batcher::new(BatchPolicy::new(sizes).unwrap().with_queue_cap(64)));
    server.config.default_step_us = 1_000;
    let step_us = server.config.default_step_us;
    let batch = 2usize;
    let hits = |p: &FaultPlan| -> Vec<u32> {
        (0..64u64)
            .flat_map(|t| (0..batch as u64).filter_map(move |i| p.member_fault(0, t, i)))
            .collect()
    };
    let plan = (0u64..200_000)
        .map(|seed| FaultPlan::new(seed, 0.05))
        .find(|p| {
            let clean = (0..2u64).all(|id| !p.admission_fault(id))
                && (0..40u64).all(|t| p.step_fault(0, t, 0).is_none())
                && (0..2u64).all(|id| (0..26u64).all(|k| !p.cache_write_fault(id, k)));
            let only = (0..64u64)
                .flat_map(|t| (0..batch as u64).map(move |i| (t, i)))
                .filter(|&(t, i)| p.member_fault(0, t, i).is_some())
                .collect::<Vec<_>>();
            // One hit, landing safely inside the live decode window.
            clean && only.len() == 1 && only[0].0 < 20
        })
        .expect("a clean single-member-fault seed exists in range (7026)");
    let mult = hits(&plan)[0];
    server.set_faults(Some(plan));
    let arrivals = ArrivalPlan {
        arrivals: (0..2)
            .map(|_| Arrival { at_us: 0, prompt_len: 4, max_new_tokens: 24 })
            .collect(),
    };
    let opts = ServeOptions::new(batch, 4).with_queue_cap(64);
    let report = server.serve_load(&arrivals, &opts).unwrap();
    assert_eq!(report.outcome_counts().0, 2, "the lone member fault must not fail anything");
    let snap = server.metrics.snapshot();
    assert_eq!(
        snap.faults.get("member_straggler").copied().unwrap_or(0),
        1,
        "the seed search guarantees exactly one member fault: {snap:?}"
    );
    let member = member_tail_penalty_us(step_us, batch, mult);
    let whole = member_tail_penalty_us(step_us, 1, mult);
    assert_eq!(
        snap.straggler_penalty_us, member,
        "the billed penalty must be exactly the slot-tail charge \
         (step {step_us}µs, batch {batch}, mult {mult})"
    );
    assert!(
        snap.straggler_penalty_us < whole,
        "a member fault must bill strictly less than a whole-step \
         straggler at the same multiplier: {} >= {whole}",
        snap.straggler_penalty_us
    );
    assert!(snap.outcomes_accounted());
    assert!(snap.preemptions_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hinted_retries_after_queue_full_shed_beat_immediate_retries() {
    // The shed hint must price actual backlog drain time (queue depth x
    // recent mean step time), so a client that waits the hint out while
    // the server works retries into a queue with room — while an
    // immediate retry always meets the same full queue.
    let dir = chaos_dir("shed-hint");
    let rt = Runtime::cpu().unwrap();
    let mut server = build_server(&rt, &dir, 2, None);
    let req = |id: u64| DecodeRequest::new(id, vec![1, 2], 4);
    let mut immediate_ok = 0usize;
    let mut hinted_ok = 0usize;
    let trials = 4u64;
    for trial in 0..trials {
        let base = 100 * trial;
        assert_eq!(server.submit(req(base)), Admission::Admitted);
        assert_eq!(server.submit(req(base + 1)), Admission::Admitted);
        let hint = match server.submit(req(base + 2)) {
            Admission::Shed { retry_after_us } => retry_after_us,
            Admission::Admitted => panic!("queue_cap 2 must shed the third submit"),
        };
        assert!(hint > 0, "shed must carry a positive retry hint");
        if trial > 0 {
            // Steps have completed by now: the hint is backlog-scaled,
            // not the max-wait constant.
            let mean = server.batcher.mean_step_us().expect("steps completed");
            assert_eq!(hint, 2 * mean, "hint = queue depth x mean step time");
        }
        // Immediate retry: same virtual instant, same full queue.
        if server.submit(req(base + 3)) == Admission::Admitted {
            immediate_ok += 1;
        }
        // Hinted retry: wait the hint out while the server drains.
        server.advance_clock(hint);
        server.drain().unwrap();
        if server.submit(req(base + 4)) == Admission::Admitted {
            hinted_ok += 1;
        }
        server.drain().unwrap();
    }
    assert_eq!(immediate_ok, 0, "immediate retries always meet the full queue");
    assert_eq!(hinted_ok as u64, trials, "hinted retries must find room");
    assert!(hinted_ok > immediate_ok, "hinted retries must succeed more often");
    assert!(server.metrics.snapshot().outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn full_rung_prices_monotonically_down_the_ladder() {
    // The never-worse argument, priced: resident <= overlapped <= layer,
    // and the warm (full-rung) route is never slower than the splitk
    // default the bottom rung would serve.
    let dir = chaos_dir("ladder");
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    let routed = router.route(4);
    assert_eq!(routed.outcome.rung, RouteRung::Full, "warm cache must serve rung 1");
    let plan = routed.plan.unwrap();
    let resident = plan.predicted_resident_ns().unwrap();
    let overlapped = plan.predicted_overlapped_ns().unwrap();
    let layer = plan.predicted_layer_ns().unwrap();
    assert!(resident <= overlapped && overlapped <= layer, "{resident} {overlapped} {layer}");
    assert_eq!(plan.predicted_served_ns(), Some(resident));

    // Bottom rung on a cold router with no re-tune budget: all splitk.
    let cold = std::env::temp_dir()
        .join(format!("w4a16-chaos-ladder-cold-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cold);
    std::fs::create_dir_all(&cold).unwrap();
    std::fs::write(cold.join("manifest.json"), manifest_json()).unwrap();
    let cold_mf = Manifest::load(&cold).unwrap();
    let mut cold_router = Router::new(&rt, cold_mf, "tiny").unwrap();
    cold_router.set_retune_budget(0);
    let bottom = cold_router.route(4);
    assert_eq!(bottom.outcome.rung, RouteRung::DefaultSplitk);
    let splitk_layer = bottom.plan.unwrap().predicted_layer_ns().unwrap();
    assert!(
        layer <= splitk_layer * 1.000001,
        "tuned layer {layer} must not be slower than the splitk default {splitk_layer}"
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&cold);
}
