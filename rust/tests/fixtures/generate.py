#!/usr/bin/env python3
"""Offline generator for the golden-trace fixtures.

Mirrors the byte accounting of `rust/src/kernels/{splitk,chunked,
data_parallel}.rs` + `analysis/golden.rs` for the pinned-tiling cases in
`rust/tests/golden_traces.rs`, and the decode-step graph construction of
`rust/src/workload/decode_layer.rs` (`DecodeStep::nodes` +
`golden::step_to_json`) for the full-step fixtures.  The canonical
regeneration path is `BLESS=1 cargo test --test golden_traces`; this
script exists so the fixtures can be (re)derived without a Rust toolchain
and cross-checks the schedule math independently.
"""

import json
import os

AI_CORES = 32
VEC_CORES = 64
CUBE_TILE = 16


def m_padded(m):
    return (m + CUBE_TILE - 1) // CUBE_TILE * CUBE_TILE


def round_robin_counts(items, engines):
    return [len(range(e, items, engines)) for e in range(engines)]


def phase(name, unit, pipelined, chunk, engines, steps, reads, writes):
    return {
        "name": name,
        "unit": unit,
        "pipelined_with_prev": pipelined,
        "chunk": chunk,
        "engines": engines,
        "steps": steps,
        "reads": {k: v for k, v in reads.items() if v > 0},
        "writes": {k: v for k, v in writes.items() if v > 0},
    }


def dequant_phase(name, chunk, n, k, t, engines, pipelined, group=128):
    k_tiles = k // t["dequant_bk"]
    n_tiles = n // t["dequant_bn"]
    tiles = k_tiles * n_tiles
    elems = t["dequant_bk"] * t["dequant_bn"]
    wp = tiles * elems // 2
    qp = tiles * 2 * (t["dequant_bk"] // group) * t["dequant_bn"] * 4
    ws = tiles * elems * 2
    return phase(
        name, "vector", pipelined, chunk, min(tiles, engines), tiles,
        {"weight_packed": wp, "quant_param": qp}, {"workspace": ws},
    )


def mmad_phase(name, chunk, pipelined, m, n, t, k_steps, with_epilogue):
    items = t["splits"] * (m_padded(m) // t["bm"]) * (n // t["bn"])
    steps = items * k_steps
    b_tile = t["bk"] * t["bn"] * 2
    a_tile = t["bm"] * t["bk"] * 2
    reads = {"workspace": steps * b_tile, "activation": steps * a_tile}
    writes = {}
    if with_epilogue:
        if t["splits"] == 1:
            writes["output"] = items * t["bm"] * t["bn"] * 2
        else:
            writes["partial"] = items * t["bm"] * t["bn"] * 4
    return phase(name, "cube", pipelined, chunk, min(items, AI_CORES), steps, reads, writes)


def reduce_phases(m, n, t, mode):
    out_tiles = (m_padded(m) // t["bm"]) * (n // t["bn"])
    elems = t["bm"] * t["bn"]
    rd = t["splits"] * elems * 4
    wr = elems * 2
    # The §11 floor-wave generalization: streaming only needs every engine
    # to own at least two tiles; uneven assignments stream their floor wave.
    streamable = mode == "pipelined" and out_tiles >= 2 * VEC_CORES
    if not streamable:
        return [phase(
            "reduce", "vector", False, None, min(out_tiles, VEC_CORES), out_tiles,
            {"partial": out_tiles * rd}, {"output": out_tiles * wr},
        )]
    stream = out_tiles - VEC_CORES
    return [
        phase("reduce_stream", "vector", True, None, VEC_CORES, stream,
              {"partial": stream * rd}, {"output": stream * wr}),
        phase("reduce_tail", "vector", False, None, VEC_CORES, VEC_CORES,
              {"partial": VEC_CORES * rd}, {"output": VEC_CORES * wr}),
    ]


def trace(name, phases, workspace_bytes, partial_bytes, policy, macs):
    return {
        "name": name,
        "workspace_bytes": workspace_bytes,
        "partial_bytes": partial_bytes,
        "workspace_policy": policy,
        "total_macs": macs,
        "phases": phases,
    }


def splitk(m, n, k, t, mode):
    mp = m_padded(m)
    k_steps = (k // t["splits"]) // t["bk"]
    phases = [
        dequant_phase("dequant", None, n, k, t, VEC_CORES, False),
        mmad_phase("splitk_mmad", None, True, m, n, t, k_steps, True),
    ]
    assert t["splits"] > 1
    phases += reduce_phases(m, n, t, mode)
    return trace(
        f"splitk_m{m}_n{n}_k{k}_s{t['splits']}", phases,
        k * n * 2, t["splits"] * mp * n * 4, "buffered", mp * n * k,
    )


def chunked(m, n, k, t, mode):
    mp = m_padded(m)
    chunks = t["chunks"]
    kc = k // chunks
    k_steps = (kc // t["splits"]) // t["bk"]
    phases = []
    for c in range(chunks):
        phases.append(dequant_phase("chunk_dequant", c, n, kc, t, VEC_CORES, c > 0))
        phases.append(mmad_phase("chunk_mmad", c, True, m, n, t, k_steps, c == chunks - 1))
    if t["splits"] > 1:
        phases += reduce_phases(m, n, t, mode)
    slice_bytes = kc * n * 2
    resident = slice_bytes * min(chunks, 2)
    return trace(
        f"chunked_m{m}_n{n}_k{k}_s{t['splits']}_c{chunks}", phases,
        resident, t["splits"] * mp * n * 4,
        {"pinned_resident_bytes": resident}, mp * n * k,
    )


def data_parallel(m, n, k, t):
    mp = m_padded(m)
    strips = (mp // t["bm"]) * (n // t["bn"])
    engines = min(strips, AI_CORES) * 2
    phases = [
        dequant_phase("dequant", None, n, k, t, min(engines, VEC_CORES), False),
        mmad_phase("dp_mmad", None, True, m, n, t, k // t["bk"], True),
    ]
    return trace(f"dp_m{m}_n{n}_k{k}", phases, k * n * 2, 0, "buffered", mp * n * k)


def tiling(bm, bn, bk, splits, chunks, rebalance=0):
    return {"bm": bm, "bn": bn, "bk": bk, "splits": splits, "chunks": chunks,
            "dequant_bk": 128, "dequant_bn": 256, "rebalance": rebalance}


# --- W4A8 precision family (kernels/w4a8.rs, DESIGN §16) -------------------


def w4a8_dequant_phase(n, k, t, group=128):
    """INT4 -> INT8 weight conversion: same packed/qparam reads as the
    W4A16 dequant, but the workspace lands at INT8 (half the bytes).
    The full/deferred step split (rebalance) moves compute ops only, so
    the byte digest is rebalance-invariant here."""
    k_tiles = k // t["dequant_bk"]
    n_tiles = n // t["dequant_bn"]
    tiles = k_tiles * n_tiles
    elems = t["dequant_bk"] * t["dequant_bn"]
    wp = tiles * elems // 2
    qp = tiles * 2 * (t["dequant_bk"] // group) * t["dequant_bn"] * 4
    return phase("w4a8_dequant", "vector", False, None, min(tiles, VEC_CORES),
                 tiles, {"weight_packed": wp, "quant_param": qp},
                 {"workspace": tiles * elems})


def w4a8_act_quant_phase(m, k, t):
    """FP16 -> INT8 activation quantize: reads the FP16 activations once,
    writes the INT8 stream the cube cores consume."""
    tiles = (m_padded(m) // 16) * (k // t["dequant_bk"])
    elems = 16 * t["dequant_bk"]
    return phase("act_quant", "vector", True, None, min(tiles, VEC_CORES),
                 tiles, {"activation": tiles * elems * 2},
                 {"workspace": tiles * elems})


def w4a8_mmad_phase(m, n, t, k_steps):
    """INT8 MMAD: both tile streams read from the workspace at INT8 width
    (half the W4A16 bytes per tile)."""
    items = t["splits"] * (m_padded(m) // t["bm"]) * (n // t["bn"])
    steps = items * k_steps
    b_tile = t["bk"] * t["bn"]
    a_tile = t["bm"] * t["bk"]
    reads = {"workspace": steps * (b_tile + a_tile)}
    if t["splits"] == 1:
        writes = {"output": items * t["bm"] * t["bn"] * 2}
    else:
        writes = {"partial": items * t["bm"] * t["bn"] * 4}
    return phase("w4a8_mmad", "cube", True, None, min(items, AI_CORES), steps,
                 reads, writes)


def w4a8_reduce_scale_phase(m, n, k, t, group=128):
    """The deferred-scale epilogue: one correction pass per deferred
    dequant tile over its m_pad x dequant_bn output strip."""
    deferred = ((k // t["dequant_bk"]) * (n // t["dequant_bn"])
                * t["rebalance"] // 100)
    assert deferred > 0, "reduce_scale only exists when tiles defer"
    mp = m_padded(m)
    out_bytes = deferred * mp * t["dequant_bn"] * 2
    qp = deferred * 2 * (t["dequant_bk"] // group) * t["dequant_bn"] * 4
    return phase("reduce_scale", "vector", t["splits"] > 1, None,
                 min(deferred, VEC_CORES), deferred,
                 {"output": out_bytes, "quant_param": qp},
                 {"output": out_bytes})


def w4a8(m, n, k, t, mode):
    mp = m_padded(m)
    k_steps = (k // t["splits"]) // t["bk"]
    phases = [
        w4a8_dequant_phase(n, k, t),
        w4a8_act_quant_phase(m, k, t),
        w4a8_mmad_phase(m, n, t, k_steps),
    ]
    if t["splits"] > 1:
        phases += reduce_phases(m, n, t, mode)
    if t["rebalance"] > 0:
        phases.append(w4a8_reduce_scale_phase(m, n, k, t))
    return trace(
        f"w4a8_m{m}_n{n}_k{k}_s{t['splits']}", phases,
        k * n + mp * k,
        t["splits"] * mp * n * 4 if t["splits"] > 1 else 0,
        "buffered", mp * n * k,
    )


# --- phase-level co-scheduler splice (analysis/coschedule.rs, DESIGN §12) ---

def merged(producer, consumer):
    """Mirror of `coschedule::splice` + `golden::merged_to_json`.

    The producer's exposed reduce tail (the trailing barrier group, all
    reduce phases) moves into the consumer's opening dequant phase:
    per-engine step sequences concatenate (reduce first, then dequant —
    both keep their own order), Partial reads re-class as carried_partial,
    and active engines become the union (both sides round-robin from
    engine 0, so the union is the max).  Everything else — chunk tags,
    workspace fields, the consumer's later phases — is untouched.
    """
    phases = producer["phases"]
    start = len(phases) - 1
    while start > 0 and phases[start]["pipelined_with_prev"]:
        start -= 1
    assert start > 0, "producer has no exposed group"
    tail = phases[start:]
    assert all(p["name"].startswith("reduce") for p in tail), "tail must be all reduce"
    head = dict(producer, name=producer["name"] + "_head", phases=phases[:start])

    dq = consumer["phases"][0]
    assert "dequant" in dq["name"], "consumer must open with a dequant prologue"
    reads = dict(dq["reads"])
    writes = dict(dq["writes"])
    steps, engines = dq["steps"], dq["engines"]
    for t in tail:
        steps += t["steps"]
        engines = max(engines, t["engines"])
        for k, v in t["reads"].items():
            key = "carried_partial" if k == "partial" else k
            reads[key] = reads.get(key, 0) + v
        for k, v in t["writes"].items():
            writes[k] = writes.get(k, 0) + v
    spliced_dq = dict(dq, name="spliced_dequant", steps=steps, engines=engines,
                      reads=reads, writes=writes)
    spliced = dict(consumer, name=consumer["name"] + "_spliced",
                   phases=[spliced_dq] + consumer["phases"][1:])
    return {"name": f"merged_{producer['name']}__{consumer['name']}",
            "kernels": [head, spliced]}


# --- step-level weight residency (analysis/residency.rs, DESIGN §13) ------


def resident(trace_doc):
    """Mirror of `residency::carry_weights` + `golden::trace_to_json`.

    Every phase's weight_packed and quant_param reads re-class as one
    carried_weight total; byte counts, writes, engines, steps, macs and
    the workspace fields are untouched (pinning changes where weight
    bytes are served, never how many).
    """
    phases = []
    for p in trace_doc["phases"]:
        reads = dict(p["reads"])
        carried = reads.pop("weight_packed", 0) + reads.pop("quant_param", 0)
        if carried:
            reads["carried_weight"] = carried
        phases.append(dict(p, reads=reads))
    return dict(trace_doc, name=trace_doc["name"] + "_resident", phases=phases)


# --- chain-level co-scheduler splice (coschedule.rs splice_chain, DESIGN §13)


def round_robin_loads(items, slots):
    return [len(range(e, items, slots)) for e in range(slots)]


def chain(producer, c1, c2):
    """Mirror of `coschedule::splice_chain` + `golden::merged_to_json`.

    The producer's exposed tail steps flatten into one carried list;
    the first consumer's dequant prologue absorbs one carried step per
    dequant step (its capacity), the second takes the overflow, and each
    prologue re-balances least-loaded over the 64 vector engines (the
    digest only needs the resulting active-engine count, which the same
    greedy integer loop computes here).  Tail steps are identical reduce
    steps, so per-step bytes divide out of the phase totals exactly.
    """
    phases = producer["phases"]
    start = len(phases) - 1
    while start > 0 and phases[start]["pipelined_with_prev"]:
        start -= 1
    assert start > 0, "producer has no exposed group"
    tail = phases[start:]
    assert all(p["name"].startswith("reduce") for p in tail), "tail must be all reduce"
    head = dict(producer, name=producer["name"] + "_head", phases=phases[:start])

    carried_steps = sum(p["steps"] for p in tail)
    rd = sum(p["reads"]["partial"] for p in tail) // carried_steps
    wr = sum(p["writes"]["output"] for p in tail) // carried_steps

    def spliced(consumer, n_carried, suffix):
        dq = consumer["phases"][0]
        assert "dequant" in dq["name"], "consumer must open with a dequant prologue"
        loads = round_robin_loads(dq["steps"], VEC_CORES)
        assigned = [0] * VEC_CORES
        for _ in range(n_carried):
            e = min(range(VEC_CORES), key=lambda i: (loads[i], i))
            loads[e] += 1
            assigned[e] += 1
        engines = sum(1 for e in range(VEC_CORES)
                      if assigned[e] > 0 or e in range(min(dq["steps"], VEC_CORES)))
        reads = dict(dq["reads"])
        writes = dict(dq["writes"])
        if n_carried:
            reads["carried_partial"] = reads.get("carried_partial", 0) + n_carried * rd
            writes["output"] = writes.get("output", 0) + n_carried * wr
        name = "spliced_dequant" if n_carried else dq["name"]
        new_dq = dict(dq, name=name, steps=dq["steps"] + n_carried,
                      engines=engines, reads=reads, writes=writes)
        return dict(consumer, name=consumer["name"] + suffix,
                    phases=[new_dq] + consumer["phases"][1:])

    cap1 = min(c1["phases"][0]["steps"], carried_steps)
    return {"name": f"chain_{producer['name']}__{c1['name']}__{c2['name']}",
            "kernels": [head,
                        spliced(c1, cap1, "_spliced"),
                        spliced(c2, carried_steps - cap1, "_spliced2")]}


# --- full decode-step graph (workload/decode_layer.rs DecodeStep::nodes) ---

def vec_node(kind, elems, ops, hbm, l2):
    return {"node": "vector", "kind": kind, "elems": elems,
            "ops_per_elem": ops, "hbm_bytes": hbm, "l2_bytes": l2}


def gemm_node(kind, m, n, k, count, group=128):
    return {"node": "gemm", "kind": kind, "m": m, "n": n, "k": k,
            "group": group, "count": count}


def decode_step(batch, kv_len, heads, hidden, ffn, kv, moe=None):
    m, h = batch, hidden
    head_dim = hidden // heads  # presets use 128-wide heads exactly
    assert head_dim * heads == hidden
    scores = m * heads * kv_len
    norm = vec_node("rmsnorm", m * h, 6, 0, 2 * m * h * 2)
    residual = vec_node("residual", m * h, 1, 0, 3 * m * h * 2)
    nodes = [
        norm,
        gemm_node("qkv", m, h + 2 * kv, h, 1),
        vec_node("attn_score", scores, 2 * head_dim,
                 m * kv_len * kv * 2, m * h * 2 + scores * 2),
        vec_node("attn_softmax", scores, 8, 0, 2 * scores * 2),
        vec_node("attn_av", scores, 2 * head_dim,
                 m * kv_len * kv * 2, scores * 2 + m * h * 2),
        gemm_node("attn_out", m, h, h, 1),
        residual,
        norm,
    ]
    if moe is None:
        nodes += [
            gemm_node("up_gate", m, 2 * ffn, h, 1),
            vec_node("activation", m * ffn, 4, 0, 3 * m * ffn * 2),
            gemm_node("down", m, h, ffn, 1),
        ]
    else:
        experts, topk, ef = moe["experts"], moe["topk"], moe["expert_ffn"]
        pairs = m * topk
        active = max(1, min(experts, pairs))
        tokens = -(-pairs // active)  # ceil division (balanced routing)
        routed = active * tokens
        nodes += [
            vec_node("moe_route", m * experts, 2 * h + 8,
                     h * experts * 2, m * h * 2 + m * experts * 2),
            gemm_node("moe_expert", tokens, 2 * ef, h, active),
            vec_node("activation", routed * ef, 4, 0, 3 * routed * ef * 2),
            gemm_node("moe_expert", tokens, h, ef, active),
        ]
    nodes.append(residual)
    return {"batch": batch, "kv_len": kv_len, "heads": heads,
            "hidden": hidden, "ffn": ffn, "kv": kv, "moe": moe, "nodes": nodes}


# --- causal prefill chunk graph (workload/prefill.rs PrefillStep::nodes) ---

def prefill_step(m, kv_base, heads, hidden, ffn, kv, moe=None):
    """Mirror of `PrefillStep::nodes` + `golden::prefill_step_to_json`:
    the decode graph with the attention passes sized by the exact causal
    context ctx = m*kv_base + m*(m+1)/2 (row i attends kv_base + i + 1
    keys), scores = heads*ctx."""
    h = hidden
    head_dim = hidden // heads  # presets use 128-wide heads exactly
    assert head_dim * heads == hidden
    ctx = m * kv_base + m * (m + 1) // 2
    scores = heads * ctx
    norm = vec_node("rmsnorm", m * h, 6, 0, 2 * m * h * 2)
    residual = vec_node("residual", m * h, 1, 0, 3 * m * h * 2)
    nodes = [
        norm,
        gemm_node("qkv", m, h + 2 * kv, h, 1),
        vec_node("attn_score", scores, 2 * head_dim,
                 ctx * kv * 2, m * h * 2 + scores * 2),
        vec_node("attn_softmax", scores, 8, 0, 2 * scores * 2),
        vec_node("attn_av", scores, 2 * head_dim,
                 ctx * kv * 2, scores * 2 + m * h * 2),
        gemm_node("attn_out", m, h, h, 1),
        residual,
        norm,
    ]
    if moe is None:
        nodes += [
            gemm_node("up_gate", m, 2 * ffn, h, 1),
            vec_node("activation", m * ffn, 4, 0, 3 * m * ffn * 2),
            gemm_node("down", m, h, ffn, 1),
        ]
    else:
        experts, topk, ef = moe["experts"], moe["topk"], moe["expert_ffn"]
        pairs = m * topk
        active = max(1, min(experts, pairs))
        tokens = -(-pairs // active)  # ceil division (balanced routing)
        routed = active * tokens
        nodes += [
            vec_node("moe_route", m * experts, 2 * h + 8,
                     h * experts * 2, m * h * 2 + m * experts * 2),
            gemm_node("moe_expert", tokens, 2 * ef, h, active),
            vec_node("activation", routed * ef, 4, 0, 3 * routed * ef * 2),
            gemm_node("moe_expert", tokens, h, ef, active),
        ]
    nodes.append(residual)
    return {"chunk": m, "kv_base": kv_base, "kv_end": kv_base + m,
            "causal_ctx": ctx, "heads": heads, "hidden": hidden, "ffn": ffn,
            "kv": kv, "moe": moe, "nodes": nodes}


FIXTURES = {
    "splitk_m8_n512_k16384_pipelined":
        splitk(8, 512, 16384, tiling(16, 256, 64, 16, 1), "pipelined"),
    "splitk_m16_n12288_k5120_pipelined":
        splitk(16, 12288, 5120, tiling(16, 64, 128, 2, 1), "pipelined"),
    "splitk_m8_n512_k16384_barrier":
        splitk(8, 512, 16384, tiling(16, 256, 64, 16, 1), "barrier"),
    # One routed expert's down-projection (DeepSeek-R1 shape): 224 output
    # tiles over 64 engines pin the uneven floor-wave streaming gate.
    "splitk_m1_n7168_k2048_pipelined":
        splitk(1, 7168, 2048, tiling(16, 32, 128, 4, 1), "pipelined"),
    "chunked_m8_n5120_k12288_pipelined":
        chunked(8, 5120, 12288, tiling(16, 256, 64, 4, 4), "pipelined"),
    "chunked_m8_n2048_k8192_pipelined":
        chunked(8, 2048, 8192, tiling(16, 128, 128, 2, 4), "pipelined"),
    "dp_m8_n2048_k7168":
        data_parallel(8, 2048, 7168, tiling(16, 256, 64, 1, 1)),
    # Co-scheduler splices (DESIGN §12): a dense adjacent pair (the K>>N
    # acceptance shape's barrier reduce into a chunked consumer's chunk-0
    # dequant) and a MoE expert-batch internal pair (one expert instance's
    # reduce_tail into the next instance of the same schedule).
    "merged_splitk_m8_n512_k16384__chunked_m8_n2048_k8192":
        merged(splitk(8, 512, 16384, tiling(16, 256, 64, 16, 1), "pipelined"),
               chunked(8, 2048, 8192, tiling(16, 128, 128, 2, 4), "pipelined")),
    "merged_moe_expert_m1_n7168_k2048_internal":
        merged(splitk(1, 7168, 2048, tiling(16, 32, 128, 4, 1), "pipelined"),
               splitk(1, 7168, 2048, tiling(16, 32, 128, 4, 1), "pipelined")),
    # Step-level weight residency (DESIGN §13): the chunked mid shape with
    # its packed-weight + qparam reads re-classed carried_weight.
    "chunked_m8_n2048_k8192_pipelined_resident":
        resident(chunked(8, 2048, 8192, tiling(16, 128, 128, 2, 4), "pipelined")),
    # Chain-level co-scheduler splice (DESIGN §13): a barrier-reduce
    # producer (224 exposed tiles) saturating a 32-step prologue; the
    # overflow re-balances into the second consumer's prologue.
    "chain_splitk_m8_n7168_k2048__splitk_m8_n512_k2048x2":
        chain(splitk(8, 7168, 2048, tiling(16, 32, 128, 4, 1), "barrier"),
              splitk(8, 512, 2048, tiling(16, 256, 128, 2, 1), "pipelined"),
              splitk(8, 512, 2048, tiling(16, 256, 128, 2, 1), "pipelined")),
    # Full decode-step graphs: GLM-4.5 dense and DeepSeek-MoE at batch 8.
    "decode_step_glm45_b8":
        decode_step(8, 2048, 40, 5120, 12288, 5120),
    "decode_step_deepseek_moe_b8":
        decode_step(8, 2048, 56, 7168, 2048, 1536,
                    moe={"experts": 256, "topk": 8, "expert_ffn": 2048}),
    # W4A8 precision family (DESIGN §16): the dense large-K acceptance
    # shape at 50% rebalance (mixed prologue + deferred-scale epilogue
    # riding the trailing reduce group), and one routed MoE expert
    # down-projection at 100% rebalance (every tile deferred).
    "w4a8_m8_n512_k16384_pipelined":
        w4a8(8, 512, 16384, tiling(16, 256, 64, 16, 1, rebalance=50),
             "pipelined"),
    "w4a8_m1_n7168_k2048_pipelined":
        w4a8(1, 7168, 2048, tiling(16, 32, 128, 4, 1, rebalance=100),
             "pipelined"),
    # Causal prefill chunk graphs (DESIGN §15): the LLaMA-3.2 dense trunk
    # ingesting a 512-token chunk mid-prompt, and the DeepSeek-MoE trunk
    # whose 256-token chunk saturates all 256 routed experts.
    "prefill_step_llama32_m512":
        prefill_step(512, 1024, 16, 2048, 8192, 2048),
    "prefill_step_deepseek_moe_m256":
        prefill_step(256, 512, 56, 7168, 2048, 1536,
                     moe={"experts": 256, "topk": 8, "expert_ffn": 2048}),
}


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    for name, doc in FIXTURES.items():
        path = os.path.join(here, f"{name}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
