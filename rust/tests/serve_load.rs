//! Continuous-batching serve-loop property suite (DESIGN.md §15):
//! seeded Poisson arrival plans driven through `Server::serve_load` on
//! synthetic (config-only) manifests, from idle trickle to deep
//! overload.
//!
//! The invariants:
//! * arrival plans are well-formed and replay bit-identically from the
//!   seed, through JSON, and through disk;
//! * outcome conservation — `admitted == completed + shed + expired +
//!   failed` — holds for every load level, queue cap, deadline, KV
//!   budget and fault rate, and the typed shed breakdown closes;
//! * the KV pager never exceeds its capacity and always drains to zero
//!   pages (no leaks), including when tight capacity sheds admissions;
//! * the same seed reproduces the same serve run bit-for-bit, and the
//!   completed token streams are invariant to the prefill chunk size;
//! * the router's re-tune token bucket refills on the virtual clock and
//!   `background_retune` promotes a degraded route to the `full` rung.

use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::coordinator::{
    BatchPolicy, Batcher, FaultPlan, Outcome, PreemptPolicy, RouteRung, Router, ServeOptions,
    Server,
};
use ascend_w4a16::runtime::artifacts::DecodeConfig;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{ArrivalPlan, DecodeLayer};

/// Three config-only decode artifacts (batch 1/2/4) — the same tiny
/// model the chaos harness serves, so the router builds synthetic
/// engines and no PJRT artifacts are needed.
fn manifest_json() -> String {
    let artifact = |batch: usize| {
        format!(
            r#"    {{
      "name": "decode_tiny_b{batch}",
      "kind": "decode",
      "path": "decode_tiny_b{batch}.hlo.txt",
      "model": "tiny",
      "batch": {batch},
      "config": {{"vocab": 512, "hidden": 256, "layers": 2, "heads": 4,
                 "ffn": 1024, "max_seq": 64, "group": 128, "params": 0}},
      "inputs": [],
      "outputs": []
    }}"#
        )
    };
    format!(
        "{{\n  \"group\": 128,\n  \"batch_sizes\": [1, 2, 4],\n  \"paper_shapes\": [],\n  \"artifacts\": [\n{},\n{},\n{}\n  ]\n}}",
        artifact(1),
        artifact(2),
        artifact(4)
    )
}

fn decode_config() -> DecodeConfig {
    DecodeConfig {
        vocab: 512,
        hidden: 256,
        layers: 2,
        heads: 4,
        ffn: 1024,
        max_seq: 64,
        group: 128,
        params: 0,
        moe_experts: 0,
        moe_topk: 0,
    }
}

/// Manifest plus a fully warmed tune cache.  Padded-M aliasing means
/// warming the compiled batches also prices every prefill chunk the
/// tests route (all M <= 16 share one padding class), so every serve
/// run here is cache-only on the `full` rung.
fn serve_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
    let mut tuner = Tuner::new(MachineConfig::ascend910());
    for batch in [1usize, 2, 4, 32] {
        let layer = DecodeLayer::from_decode_config(&decode_config(), batch);
        for node in layer.gemm_nodes() {
            tuner.resolve(&node.problem).unwrap();
        }
        for pair in layer.overlap_pairs() {
            tuner.resolve_overlap(&pair.producer, &pair.consumer).unwrap();
        }
        tuner.resolve_residency(&layer).unwrap();
    }
    tuner.save_to(dir.join("tune_cache.json")).unwrap();
    dir
}

/// Manifest only — no tune cache — for the degradation-ladder tests.
fn cold_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-serve-cold-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
    dir
}

fn build_server<'rt>(rt: &'rt Runtime, dir: &std::path::Path) -> Server<'rt> {
    let mf = Manifest::load(dir).unwrap();
    let router = Router::new(rt, mf, "tiny").unwrap();
    let policy = BatchPolicy::new(router.batch_sizes()).unwrap();
    Server::new(router, Batcher::new(policy))
}

#[test]
fn poisson_plans_are_well_formed_and_seed_stable() {
    forall("poisson plan shape", 24, |rng| {
        let seed = rng.next_u64();
        let mean_gap_us = rng.f64() * 2_000.0;
        let count = rng.usize_range(1, 64);
        // Down to max_seq = 4: the degenerate-range regression — small
        // budgets used to invert the output-budget sampling range
        // (lo > hi) and underflow the PRNG's modulus.
        let max_seq = rng.usize_range(4, 256);
        let plan = ArrivalPlan::poisson(seed, mean_gap_us, count, max_seq);
        if plan.arrivals.len() != count {
            return (false, format!("{} arrivals != {count}", plan.arrivals.len()));
        }
        let mut last = 0u64;
        for a in &plan.arrivals {
            if a.at_us <= last {
                return (false, format!("arrival times must strictly increase: {a:?}"));
            }
            last = a.at_us;
            if a.prompt_len < 2 {
                return (false, format!("prompt too short: {a:?}"));
            }
            if a.max_new_tokens < 1 {
                return (false, format!("empty generation budget: {a:?}"));
            }
            if a.prompt_len + a.max_new_tokens >= max_seq {
                return (false, format!("overflows max_seq {max_seq}: {a:?}"));
            }
        }
        let offered: u64 = plan.arrivals.iter().map(|a| a.max_new_tokens as u64).sum();
        if plan.offered_tokens() != offered {
            return (false, "offered_tokens mismatch".into());
        }
        if plan.horizon_us() != last {
            return (false, "horizon must be the last arrival".into());
        }
        if plan != ArrivalPlan::poisson(seed, mean_gap_us, count, max_seq) {
            return (false, "same seed must replay the same plan".into());
        }
        (true, String::new())
    });
}

#[test]
fn arrival_plan_round_trips_through_json_and_disk() {
    let plan = ArrivalPlan::poisson(17, 120.0, 32, 64);
    let back = ArrivalPlan::from_json(&plan.to_json()).unwrap();
    assert_eq!(plan, back, "to_json -> from_json must be the identity");

    let path = std::env::temp_dir()
        .join(format!("w4a16-serve-plan-{}.json", std::process::id()));
    plan.save(&path).unwrap();
    let loaded = ArrivalPlan::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(plan, loaded, "save -> load must be the identity");

    // The reloaded plan drives the identical serve run.
    let dir = serve_dir("roundtrip");
    let rt = Runtime::cpu().unwrap();
    let opts = ServeOptions::new(4, 4).with_queue_cap(6);
    let mut server = build_server(&rt, &dir);
    let a = server.serve_load(&plan, &opts).unwrap();
    let mut server = build_server(&rt, &dir);
    let b = server.serve_load(&loaded, &opts).unwrap();
    assert_eq!(a.horizon_us, b.horizon_us);
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!((x.id, &x.tokens, x.outcome), (y.id, &y.tokens, y.outcome));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_conservation_property_up_to_overload() {
    // The §14 conservation law on the serve path, across the whole knob
    // space: mean gaps from idle to deep overload, random queue caps,
    // deadlines, tight KV budgets and fault rates.  Every case must
    // account every request, close the typed shed breakdown, respect the
    // pager capacity and drain the pager to zero.
    let dir = serve_dir("conserve");
    let rt = Runtime::cpu().unwrap();
    forall("serve conservation", 10, |rng| {
        let n = rng.usize_range(1, 40);
        let mean_gap_us = 10f64.powf(rng.f64() * 4.0); // 1 us .. 10 ms
        let plan = ArrivalPlan::poisson(rng.next_u64(), mean_gap_us, n, 64);
        let batch = [1usize, 2, 4][rng.usize_range(0, 2)];
        let chunk = rng.usize_range(1, 8);
        let mut opts =
            ServeOptions::new(batch, chunk).with_queue_cap(rng.usize_range(1, 16));
        if rng.f64() < 0.4 {
            opts = opts.with_deadline_us(rng.usize_range(1, 60_000) as u64);
        }
        if rng.f64() < 0.4 {
            // Tight paging: worst-case requests need up to 24 such pages.
            let pages = rng.usize_range(1, 64) as u64;
            opts = opts.with_page_bytes(4096).with_kv_capacity_bytes(pages * 4096);
        }
        // Half the cases arm a preemption policy, so the conservation
        // law is exercised with victims parked, resumed and lost.
        let preempt = [
            PreemptPolicy::Off,
            PreemptPolicy::Off,
            PreemptPolicy::Recompute,
            PreemptPolicy::Swap,
            PreemptPolicy::Auto,
        ][rng.usize_range(0, 4)];
        opts = opts
            .with_preempt(preempt)
            .with_max_preemptions(rng.usize_range(1, 4) as u32);
        let mut server = build_server(&rt, &dir);
        if rng.f64() < 0.5 {
            server.set_faults(Some(FaultPlan::new(rng.next_u64(), rng.f64() * 0.5)));
        }
        let report = match server.serve_load(&plan, &opts) {
            Ok(r) => r,
            Err(e) => return (false, format!("serve_load errored: {e:#}")),
        };
        if !report.kv_idle {
            return (false, "kv pager leaked pages".into());
        }
        if report.kv_peak_pages > report.kv_capacity_pages {
            return (
                false,
                format!(
                    "pager peak {} exceeds capacity {}",
                    report.kv_peak_pages, report.kv_capacity_pages
                ),
            );
        }
        let snap = server.metrics.snapshot();
        if snap.requests_admitted != n as u64 {
            return (false, format!("admitted {} != offered {n}", snap.requests_admitted));
        }
        if !snap.outcomes_accounted() {
            return (
                false,
                format!(
                    "admitted {} != {} + {} + {} + {}",
                    snap.requests_admitted,
                    snap.requests_completed,
                    snap.requests_shed,
                    snap.requests_expired,
                    snap.requests_failed
                ),
            );
        }
        if !snap.sheds_accounted() {
            return (false, format!("typed sheds must close: {:?}", snap.shed_reasons));
        }
        if !snap.preemptions_accounted() {
            return (
                false,
                format!(
                    "preemption ledger must close: {} preempted != {} resumed + {} lost \
                     (or != {} recompute + {} swap)",
                    snap.requests_preempted,
                    snap.requests_resumed,
                    snap.requests_preempt_failed,
                    snap.preempt_recompute,
                    snap.preempt_swap
                ),
            );
        }
        let terminal = snap.requests_completed + snap.requests_expired + snap.requests_failed;
        if report.results.len() as u64 != terminal {
            return (
                false,
                format!("{} results != {terminal} terminal outcomes", report.results.len()),
            );
        }
        for r in &report.results {
            match r.outcome {
                Outcome::Completed => {
                    if r.tokens.is_empty() || r.error.is_some() {
                        return (false, format!("malformed completion {}", r.id));
                    }
                }
                Outcome::Failed => {
                    if r.error.is_none() {
                        return (false, format!("failed {} without a cause", r.id));
                    }
                }
                Outcome::Expired => {}
            }
        }
        (true, String::new())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tight_kv_capacity_sheds_typed_and_never_leaks() {
    // One worst-case reservation (~24 pages of 4 KiB at 48 tokens of
    // 2 KiB each) nearly fills a 30-page budget, so a rapid burst must
    // shed `kv_capacity` while the admitted requests all complete.
    let dir = serve_dir("kvtight");
    let rt = Runtime::cpu().unwrap();
    let plan = ArrivalPlan::poisson(5, 2.0, 24, 64);
    let opts = ServeOptions::new(4, 4)
        .with_queue_cap(1024)
        .with_page_bytes(4096)
        .with_kv_capacity_bytes(30 * 4096);
    let mut server = build_server(&rt, &dir);
    let report = server.serve_load(&plan, &opts).unwrap();
    assert!(report.kv_idle, "pager must drain");
    assert_eq!(report.kv_capacity_pages, 30);
    assert!(report.kv_peak_pages <= 30, "peak {} > capacity", report.kv_peak_pages);
    let snap = server.metrics.snapshot();
    assert!(snap.outcomes_accounted());
    assert!(snap.sheds_accounted());
    assert!(snap.preemptions_accounted());
    let kv_sheds = snap.shed_reasons.get("kv_capacity").copied().unwrap_or(0);
    assert!(kv_sheds > 0, "a 30-page budget must shed this burst: {:?}", snap.shed_reasons);
    assert!(snap.requests_completed > 0, "admitted requests must still complete");
    assert_eq!(snap.requests_completed + snap.requests_shed, 24);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_replay_is_bit_identical() {
    // Same plan, same knobs, fresh servers: the virtual clock, outcome
    // ledger and every token stream must replay exactly — including
    // under overload where shed decisions interleave with ticks.
    let dir = serve_dir("replay");
    let rt = Runtime::cpu().unwrap();
    let plan = ArrivalPlan::poisson(29, 5.0, 24, 64);
    let opts = ServeOptions::new(4, 4).with_queue_cap(4);

    let mut server_a = build_server(&rt, &dir);
    let a = server_a.serve_load(&plan, &opts).unwrap();
    let mut server_b = build_server(&rt, &dir);
    let b = server_b.serve_load(&plan, &opts).unwrap();

    assert_eq!(a.horizon_us, b.horizon_us, "virtual clocks diverged");
    assert_eq!(a.kv_peak_pages, b.kv_peak_pages);
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.id, y.id, "result order diverged");
        assert_eq!(x.outcome, y.outcome, "outcome diverged for {}", x.id);
        assert_eq!(x.tokens, y.tokens, "tokens diverged for {}", x.id);
        assert_eq!(x.steps, y.steps, "tick counts diverged for {}", x.id);
    }
    let sa = server_a.metrics.snapshot();
    let sb = server_b.metrics.snapshot();
    assert_eq!(
        (sa.requests_completed, sa.requests_shed, sa.tokens_generated),
        (sb.requests_completed, sb.requests_shed, sb.tokens_generated)
    );
    assert_eq!(
        (sa.prefill_steps, sa.prefill_tokens, sa.decode_steps, sa.repins),
        (sb.prefill_steps, sb.prefill_tokens, sb.decode_steps, sb.repins)
    );
    assert!(sa.requests_shed > 0, "this overload case must exercise shedding");
    assert!(sa.preemptions_accounted() && sb.preemptions_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn completed_tokens_are_invariant_to_prefill_chunk_size() {
    // The chunk size moves prefill tick boundaries (and therefore the
    // clock), but never the token streams: the final prompt token is
    // always fed by the first decode tick, so generation is position-
    // exact for any chunking.  With an unbounded queue every request
    // completes, whatever the chunking.
    let dir = serve_dir("chunkinv");
    let rt = Runtime::cpu().unwrap();
    let plan = ArrivalPlan::poisson(21, 50.0, 10, 64);
    let mut baseline: Option<std::collections::BTreeMap<u64, Vec<i32>>> = None;
    for chunk in [1usize, 2, 5, 32] {
        let opts = ServeOptions::new(4, chunk).with_queue_cap(1024);
        let mut server = build_server(&rt, &dir);
        let report = server.serve_load(&plan, &opts).unwrap();
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests_completed, 10, "chunk {chunk}: all must complete");
        assert!(snap.outcomes_accounted());
        assert!(snap.preemptions_accounted());
        assert!(report.kv_idle);
        let tokens: std::collections::BTreeMap<u64, Vec<i32>> =
            report.results.into_iter().map(|r| (r.id, r.tokens)).collect();
        match &baseline {
            None => baseline = Some(tokens),
            Some(base) => {
                assert_eq!(base, &tokens, "chunk {chunk} changed a completed token stream")
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn retune_token_bucket_refills_on_the_virtual_clock() {
    // The DESIGN.md §15 token bucket, walked up the ladder: an empty
    // bucket serves the splitk default; banked credits pay inline
    // re-tunes (rung `retuned`); once the shape winners are cached the
    // cleared route re-resolves at `tuned_only`; and a background
    // re-tune fills the cross-node gains, promoting the route to `full`.
    let dir = cold_dir("bucket");
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    router.set_retune_budget(0);
    router.set_retune_refill(1_000, 8);

    assert_eq!(router.route(4).outcome.rung, RouteRung::DefaultSplitk);
    router.advance_clock(999); // below one interval: no credit lands
    assert_eq!(router.retune_budget(), 0);
    assert_eq!(router.route(4).outcome.rung, RouteRung::DefaultSplitk);

    // Four intervals bank four credits — one per GEMM node of the tiny
    // dense layer — and clear the memoized routes.
    router.advance_clock(4_000);
    assert_eq!(router.retune_budget(), 4);
    assert_eq!(router.route(4).outcome.rung, RouteRung::Retuned);
    assert_eq!(router.retune_budget(), 0, "each inline re-tune spends a credit");

    // The winners are cached now: after the next refill clears the
    // route, re-resolution is cache-only but the gains are still cold.
    router.advance_clock(5_500);
    assert_eq!(router.retune_budget(), 1);
    assert_eq!(router.route(4).outcome.rung, RouteRung::TunedOnly);

    // Background re-tune pays the pair + residency searches off the
    // serving path and drops the route: the next lookup serves `full`.
    router.background_retune(4).unwrap();
    assert_eq!(router.route(4).outcome.rung, RouteRung::Full);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn background_retune_promotes_a_cold_route_to_full() {
    let dir = cold_dir("promote");
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    router.set_retune_budget(16);
    assert_eq!(router.route(2).outcome.rung, RouteRung::Retuned);
    router.background_retune(2).unwrap();
    let routed = router.route(2);
    assert_eq!(routed.outcome.rung, RouteRung::Full);
    let plan = routed.plan.unwrap();
    assert!(plan.predicted_served_ns().is_some(), "a full route must price the group");
    let _ = std::fs::remove_dir_all(&dir);
}
