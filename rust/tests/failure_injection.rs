//! Failure injection: corrupt manifests, truncated weight blobs, malformed
//! HLO — every boundary the runtime trusts must fail loudly, not silently.

use std::io::Write;

use ascend_w4a16::runtime::{Manifest, Runtime};

fn write_file(dir: &std::path::Path, name: &str, content: &str) {
    let mut f = std::fs::File::create(dir.join(name)).unwrap();
    f.write_all(content.as_bytes()).unwrap();
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-fi-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const MINIMAL_MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "gemm_a", "kind": "gemm", "path": "gemm_a.hlo.txt",
      "strategy": "splitk", "m": 4, "n": 8, "k": 16, "group": 128, "splits": 1,
      "inputs": [{"name": "a", "dtype": "f32", "shape": [4, 16]}],
      "outputs": [{"name": "c", "dtype": "f32", "shape": [4, 8]}]
    }
  ],
  "paper_shapes": [{"model": "x", "n": 8, "k": 16}],
  "batch_sizes": [1],
  "group": 128
}"#;

#[test]
fn missing_manifest_mentions_make_artifacts() {
    let dir = tmpdir("nomanifest");
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
}

#[test]
fn malformed_json_reports_position() {
    let dir = tmpdir("badjson");
    write_file(&dir, "manifest.json", "{\"version\": 1, \"artifacts\": [");
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("json parse error"), "{err}");
}

#[test]
fn missing_required_key_is_named() {
    let dir = tmpdir("nokey");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version": 1, "artifacts": [{"kind": "gemm"}], "paper_shapes": [], "batch_sizes": [], "group": 128}"#,
    );
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("'name'"), "{err}");
}

#[test]
fn unknown_dtype_rejected() {
    let dir = tmpdir("baddtype");
    write_file(
        &dir,
        "manifest.json",
        &MINIMAL_MANIFEST.replace("\"dtype\": \"f32\", \"shape\": [4, 16]",
                                   "\"dtype\": \"bf8\", \"shape\": [4, 16]"),
    );
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("bf8"), "{err}");
}

#[test]
fn truncated_weight_blob_detected() {
    let dir = tmpdir("shortblob");
    let manifest = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "d", "kind": "decode", "path": "d.hlo.txt", "model": "t", "batch": 1,
          "config": {"vocab": 8, "hidden": 8, "layers": 1, "heads": 1, "ffn": 8,
                     "max_seq": 4, "group": 128, "params": 64},
          "weights": {"path": "d_weights.bin", "total_bytes": 256, "tensors": [
            {"name": "w", "dtype": "f32", "shape": [8, 8], "offset": 0, "nbytes": 256}
          ]},
          "inputs": [], "outputs": []
        }
      ],
      "paper_shapes": [], "batch_sizes": [1], "group": 128
    }"#;
    write_file(&dir, "manifest.json", manifest);
    std::fs::write(dir.join("d_weights.bin"), vec![0u8; 100]).unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let err = mf.artifacts[0]
        .weights
        .as_ref()
        .unwrap()
        .load()
        .unwrap_err()
        .to_string();
    assert!(err.contains("256"), "{err}");
}

#[test]
fn record_size_mismatch_detected() {
    let dir = tmpdir("badrecord");
    let manifest = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "d", "kind": "decode", "path": "d.hlo.txt", "model": "t", "batch": 1,
          "weights": {"path": "d_weights.bin", "total_bytes": 100, "tensors": [
            {"name": "w", "dtype": "f32", "shape": [8, 8], "offset": 0, "nbytes": 100}
          ]},
          "inputs": [], "outputs": []
        }
      ],
      "paper_shapes": [], "batch_sizes": [1], "group": 128
    }"#;
    write_file(&dir, "manifest.json", manifest);
    std::fs::write(dir.join("d_weights.bin"), vec![0u8; 100]).unwrap();
    let mf = Manifest::load(&dir).unwrap();
    // nbytes (100) != 8*8*4 (256): must be rejected.
    let err = mf.artifacts[0].weights.as_ref().unwrap().load().unwrap_err().to_string();
    assert!(err.contains("size mismatch"), "{err}");
}

#[test]
fn garbage_hlo_fails_at_compile_not_execute() {
    let dir = tmpdir("badhlo");
    write_file(&dir, "manifest.json", MINIMAL_MANIFEST);
    write_file(&dir, "gemm_a.hlo.txt", "this is not an HLO module");
    let mf = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    assert!(rt.load(mf.find("gemm_a").unwrap()).is_err());
}

#[test]
fn missing_hlo_file_is_a_clean_error() {
    let dir = tmpdir("nohlo");
    write_file(&dir, "manifest.json", MINIMAL_MANIFEST);
    let mf = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let err = match rt.load(mf.find("gemm_a").unwrap()) {
        Ok(_) => panic!("loading a missing HLO file must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("gemm_a.hlo.txt"), "{err}");
}
