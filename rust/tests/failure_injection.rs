//! Failure injection: corrupt manifests, truncated weight blobs, malformed
//! HLO — every boundary the runtime trusts must fail loudly, not silently.
//!
//! Plus the DESIGN.md §14 routing half: a corrupt, truncated or
//! stale-tagged *tune cache* is NOT fatal — the router records the
//! condition and walks the degradation ladder, and the serving loop keeps
//! completing requests.

use std::io::Write;

use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::coordinator::{
    BatchPolicy, Batcher, DecodeRequest, Outcome, RouteReason, RouteRung, Router, Server,
};
use ascend_w4a16::model::Precision;
use ascend_w4a16::runtime::artifacts::DecodeConfig;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::workload::DecodeLayer;

fn write_file(dir: &std::path::Path, name: &str, content: &str) {
    let mut f = std::fs::File::create(dir.join(name)).unwrap();
    f.write_all(content.as_bytes()).unwrap();
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-fi-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const MINIMAL_MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "gemm_a", "kind": "gemm", "path": "gemm_a.hlo.txt",
      "strategy": "splitk", "m": 4, "n": 8, "k": 16, "group": 128, "splits": 1,
      "inputs": [{"name": "a", "dtype": "f32", "shape": [4, 16]}],
      "outputs": [{"name": "c", "dtype": "f32", "shape": [4, 8]}]
    }
  ],
  "paper_shapes": [{"model": "x", "n": 8, "k": 16}],
  "batch_sizes": [1],
  "group": 128
}"#;

#[test]
fn missing_manifest_mentions_make_artifacts() {
    let dir = tmpdir("nomanifest");
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
}

#[test]
fn malformed_json_reports_position() {
    let dir = tmpdir("badjson");
    write_file(&dir, "manifest.json", "{\"version\": 1, \"artifacts\": [");
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("json parse error"), "{err}");
}

#[test]
fn missing_required_key_is_named() {
    let dir = tmpdir("nokey");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version": 1, "artifacts": [{"kind": "gemm"}], "paper_shapes": [], "batch_sizes": [], "group": 128}"#,
    );
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("'name'"), "{err}");
}

#[test]
fn unknown_dtype_rejected() {
    let dir = tmpdir("baddtype");
    write_file(
        &dir,
        "manifest.json",
        &MINIMAL_MANIFEST.replace("\"dtype\": \"f32\", \"shape\": [4, 16]",
                                   "\"dtype\": \"bf8\", \"shape\": [4, 16]"),
    );
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("bf8"), "{err}");
}

#[test]
fn truncated_weight_blob_detected() {
    let dir = tmpdir("shortblob");
    let manifest = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "d", "kind": "decode", "path": "d.hlo.txt", "model": "t", "batch": 1,
          "config": {"vocab": 8, "hidden": 8, "layers": 1, "heads": 1, "ffn": 8,
                     "max_seq": 4, "group": 128, "params": 64},
          "weights": {"path": "d_weights.bin", "total_bytes": 256, "tensors": [
            {"name": "w", "dtype": "f32", "shape": [8, 8], "offset": 0, "nbytes": 256}
          ]},
          "inputs": [], "outputs": []
        }
      ],
      "paper_shapes": [], "batch_sizes": [1], "group": 128
    }"#;
    write_file(&dir, "manifest.json", manifest);
    std::fs::write(dir.join("d_weights.bin"), vec![0u8; 100]).unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let err = mf.artifacts[0]
        .weights
        .as_ref()
        .unwrap()
        .load()
        .unwrap_err()
        .to_string();
    assert!(err.contains("256"), "{err}");
}

#[test]
fn record_size_mismatch_detected() {
    let dir = tmpdir("badrecord");
    let manifest = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "d", "kind": "decode", "path": "d.hlo.txt", "model": "t", "batch": 1,
          "weights": {"path": "d_weights.bin", "total_bytes": 100, "tensors": [
            {"name": "w", "dtype": "f32", "shape": [8, 8], "offset": 0, "nbytes": 100}
          ]},
          "inputs": [], "outputs": []
        }
      ],
      "paper_shapes": [], "batch_sizes": [1], "group": 128
    }"#;
    write_file(&dir, "manifest.json", manifest);
    std::fs::write(dir.join("d_weights.bin"), vec![0u8; 100]).unwrap();
    let mf = Manifest::load(&dir).unwrap();
    // nbytes (100) != 8*8*4 (256): must be rejected.
    let err = mf.artifacts[0].weights.as_ref().unwrap().load().unwrap_err().to_string();
    assert!(err.contains("size mismatch"), "{err}");
}

#[test]
fn garbage_hlo_fails_at_compile_not_execute() {
    let dir = tmpdir("badhlo");
    write_file(&dir, "manifest.json", MINIMAL_MANIFEST);
    write_file(&dir, "gemm_a.hlo.txt", "this is not an HLO module");
    let mf = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    assert!(rt.load(mf.find("gemm_a").unwrap()).is_err());
}

#[test]
fn missing_hlo_file_is_a_clean_error() {
    let dir = tmpdir("nohlo");
    write_file(&dir, "manifest.json", MINIMAL_MANIFEST);
    let mf = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let err = match rt.load(mf.find("gemm_a").unwrap()) {
        Ok(_) => panic!("loading a missing HLO file must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("gemm_a.hlo.txt"), "{err}");
}

// ---------------------------------------------------------------------------
// Tune-cache failure injection: the degradation ladder (DESIGN.md §14).
// ---------------------------------------------------------------------------

/// A config-only decode artifact (no weights, no HLO on disk): the router
/// builds a synthetic engine for it, so the full serving loop runs.
const DECODE_MANIFEST: &str = r#"{
  "group": 128,
  "batch_sizes": [4],
  "paper_shapes": [],
  "artifacts": [
    {
      "name": "decode_tiny_b4",
      "kind": "decode",
      "path": "decode_tiny_b4.hlo.txt",
      "model": "tiny",
      "batch": 4,
      "config": {"vocab": 512, "hidden": 256, "layers": 2, "heads": 4,
                 "ffn": 1024, "max_seq": 64, "group": 128, "params": 0},
      "inputs": [],
      "outputs": []
    }
  ]
}"#;

fn decode_config() -> DecodeConfig {
    DecodeConfig {
        vocab: 512,
        hidden: 256,
        layers: 2,
        heads: 4,
        ffn: 1024,
        max_seq: 64,
        group: 128,
        params: 0,
        moe_experts: 0,
        moe_topk: 0,
    }
}

/// Tune every shape of the decode layer on `machine` and persist the
/// cache next to the manifest in `dir`.
fn warm_cache_for(dir: &std::path::Path, machine: MachineConfig) {
    let mut tuner = Tuner::new(machine);
    for node in DecodeLayer::from_decode_config(&decode_config(), 4).gemm_nodes() {
        tuner.resolve(&node.problem).unwrap();
    }
    tuner.save_to(dir.join("tune_cache.json")).unwrap();
}

/// Serve two requests end to end and return the server for inspection.
fn serve_two<'rt>(rt: &'rt Runtime, dir: &std::path::Path) -> Server<'rt> {
    let mf = Manifest::load(dir).unwrap();
    let router = Router::new(rt, mf, "tiny").unwrap();
    let sizes = router.batch_sizes();
    let mut server = Server::new(router, Batcher::new(BatchPolicy::new(sizes).unwrap()));
    server.submit(DecodeRequest::new(1, vec![3, 5], 4));
    server.submit(DecodeRequest::new(2, vec![7], 4));
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 2);
    assert!(results.iter().all(|r| r.outcome == Outcome::Completed), "{results:?}");
    server
}

#[test]
fn corrupt_tune_cache_routes_down_the_ladder_not_abort() {
    let dir = tmpdir("badcache");
    write_file(&dir, "manifest.json", DECODE_MANIFEST);
    write_file(&dir, "tune_cache.json", "{ this is not json ]");
    let rt = Runtime::cpu().unwrap();
    // Router construction must survive the unreadable cache...
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(!router.has_tune_cache());
    // ...and routing lands on the re-tune rung, naming the cause.
    let routed = router.route(4);
    assert_eq!(routed.outcome.reason, RouteReason::CacheUnreadable);
    assert_eq!(routed.outcome.rung, RouteRung::Retuned);
    assert!(routed.outcome.detail.is_some(), "parse error must be carried");
    assert!(routed.plan.unwrap().fully_resolved());

    // The full serving loop completes, and the rung lands in metrics.
    let server = serve_two(&rt, &dir);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.route_rungs.get("retuned"), Some(&1));
    assert_eq!(snap.route_reasons.get("cache_unreadable"), Some(&1));
    assert!(snap.outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_tune_cache_degrades_like_a_corrupt_one() {
    let dir = tmpdir("shortcache");
    write_file(&dir, "manifest.json", DECODE_MANIFEST);
    warm_cache_for(&dir, MachineConfig::ascend910());
    // Truncate the valid cache mid-document.
    let full = std::fs::read_to_string(dir.join("tune_cache.json")).unwrap();
    assert!(full.len() > 40, "cache unexpectedly small");
    std::fs::write(dir.join("tune_cache.json"), &full[..full.len() / 2]).unwrap();

    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(!router.has_tune_cache());
    let routed = router.route(4);
    assert_eq!(routed.outcome.reason, RouteReason::CacheUnreadable);
    assert_eq!(routed.outcome.rung, RouteRung::Retuned);
    assert!(routed.plan.unwrap().fully_resolved());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_machine_tag_retunes_for_this_machine() {
    // A cache tuned on different hardware (its keys carry another machine
    // tag) must not serve: every lookup misses, the router re-tunes for
    // THIS machine and names the staleness as the reason.
    let dir = tmpdir("staletag");
    write_file(&dir, "manifest.json", DECODE_MANIFEST);
    let mut other = MachineConfig::ascend910();
    other.ai_cores = 8; // different tag prefix: aic8_...
    warm_cache_for(&dir, other);

    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(router.has_tune_cache(), "the file itself is readable");
    let routed = router.route(4);
    assert_eq!(routed.outcome.reason, RouteReason::StaleMachineTag);
    assert_eq!(routed.outcome.rung, RouteRung::Retuned);
    assert!(routed.plan.unwrap().fully_resolved());

    let server = serve_two(&rt, &dir);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.route_reasons.get("stale_machine_tag"), Some(&1));
    assert!(snap.outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pre_precision_cache_misses_w4a8_and_routes_down_the_ladder() {
    // A tune cache written before the precision family existed (or by a
    // W4A16-only tuner) carries no `_a8` keys.  Switching the router to
    // W4A8 must NOT abort and must NOT mis-serve W4A16 winners: every
    // W4A8 lookup misses and the plan resolves down the PR 6 ladder
    // (re-tune rung under the budget), while W4A16 routing on the same
    // cache still serves tuned, cache-only.
    let dir = tmpdir("prea8cache");
    write_file(&dir, "manifest.json", DECODE_MANIFEST);
    warm_cache_for(&dir, MachineConfig::ascend910());

    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(router.has_tune_cache());

    // W4A16 (the default): the untagged keys hit as before.
    let routed = router.route(4);
    assert!(
        matches!(routed.outcome.rung, RouteRung::Full | RouteRung::TunedOnly),
        "untagged cache must keep serving W4A16: {:?}",
        routed.outcome
    );
    assert_eq!(routed.outcome.retuned_nodes, 0);

    // W4A8: every shape key now carries the `_a8` suffix, so the
    // pre-precision cache misses and rung 3 re-tunes inline.
    router.set_precision(Precision::W4A8);
    assert_eq!(router.precision(), Precision::W4A8);
    let routed = router.route(4);
    assert_eq!(routed.outcome.rung, RouteRung::Retuned);
    assert_eq!(routed.outcome.reason, RouteReason::ShapeMiss);
    assert_eq!(routed.outcome.defaulted_nodes, 0);
    assert!(routed.outcome.retuned_nodes > 0);
    assert!(routed.plan.unwrap().fully_resolved());

    // With the budget exhausted instead (a fresh router, so the inline
    // re-tunes above haven't warmed its in-memory cache), the same miss
    // lands on the safe splitk default — degraded accounting, still
    // never an error.
    let mf = Manifest::load(&dir).unwrap();
    let mut broke = Router::new(&rt, mf, "tiny").unwrap();
    broke.set_precision(Precision::W4A8);
    broke.set_retune_budget(0);
    let routed = broke.route(4);
    assert_eq!(routed.outcome.rung, RouteRung::DefaultSplitk);
    assert!(routed.outcome.defaulted_nodes > 0);
    assert!(routed.plan.unwrap().fully_resolved());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tune_cache_deleted_mid_serve_degrades_on_the_next_router() {
    // Acceptance: deleting the cache file between serves routes the next
    // router down the ladder (counted fallback) instead of erroring.
    let dir = tmpdir("delcache");
    write_file(&dir, "manifest.json", DECODE_MANIFEST);
    warm_cache_for(&dir, MachineConfig::ascend910());
    let rt = Runtime::cpu().unwrap();
    {
        let mf = Manifest::load(&dir).unwrap();
        let mut router = Router::new(&rt, mf, "tiny").unwrap();
        let routed = router.route(4);
        assert!(
            matches!(routed.outcome.rung, RouteRung::Full | RouteRung::TunedOnly),
            "warm cache must serve tuned: {:?}",
            routed.outcome
        );
    }
    std::fs::remove_file(dir.join("tune_cache.json")).unwrap();
    let server = serve_two(&rt, &dir);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.route_reasons.get("no_cache_file"), Some(&1));
    assert_eq!(snap.route_rungs.get("retuned"), Some(&1));
    assert!(snap.outcomes_accounted());
    let _ = std::fs::remove_dir_all(&dir);
}
