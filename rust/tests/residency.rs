//! Step-level weight-residency invariants (DESIGN.md §13): the planner
//! never pins past the retained L2 capacity, the resident plan is never
//! slower than the PR-4 Auto plan (structural — `Auto` serves the min),
//! and pinning conserves weight bytes — it changes *where* they are
//! served, never *how many* move — on randomized dense and MoE decode
//! geometries.

use ascend_w4a16::analysis::layer::{forced_split_resolver, OverlapMode, Resolution};
use ascend_w4a16::analysis::stepsim::StepSim;
use ascend_w4a16::analysis::residency::{
    self, carry_weights, pin_budget_bytes, ResidencyMode,
};
use ascend_w4a16::ascend::{BufferClass, MachineConfig, ResidencyLedger, Simulator};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::model::llm::{LayerGeometry, MoeGeometry};
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{DecodeLayer, DecodeStep};

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

/// Random legal decoder-layer geometry, sometimes MoE (mirrors
/// `tests/coschedule.rs`).
fn random_step(rng: &mut ascend_w4a16::util::prng::Rng) -> DecodeStep {
    let hidden = 128 * rng.usize_range(2, 24);
    let ffn = 128 * rng.usize_range(2, 32);
    let kv = 16 * rng.usize_range(1, hidden / 16);
    let geometry = LayerGeometry { hidden, ffn, kv, group: 128 };
    let batch = rng.usize_range(1, 64);
    let mut layer = DecodeLayer::new(geometry, batch);
    if rng.usize_range(0, 1) == 1 {
        let experts = *rng.choose(&[4usize, 8, 64]);
        let topk = (*rng.choose(&[1usize, 2])).min(experts);
        layer = layer.with_moe(MoeGeometry { experts, topk, expert_ffn: ffn });
    }
    let kv_len = 128 * rng.usize_range(1, 32);
    DecodeStep::new(layer, kv_len, DecodeStep::default_heads(&geometry))
}

type Assignment = (Strategy, kernels::tiling::Tiling, Resolution);

/// Fixed-strategy resolver (fused — the planner's main beneficiary).
fn fused(m: &MachineConfig) -> impl FnMut(&GemmProblem) -> anyhow::Result<Assignment> + '_ {
    move |p| {
        Ok((
            Strategy::Fused,
            kernels::select_tiling(m, p, Strategy::Fused)?,
            Resolution::Heuristic,
        ))
    }
}

#[test]
fn pinning_never_exceeds_capacity_property() {
    let m = machine();
    let budget = pin_budget_bytes(&m);
    forall("pins fit the retained capacity", 6, |rng| {
        let step = random_step(rng);
        if step.layer.validate().is_err() {
            return (false, format!("illegal geometry {:?}", step.layer.geometry));
        }
        let rep = match StepSim::new(&m, &step)
            .overlap(OverlapMode::Sequential)
            .residency(ResidencyMode::Auto)
            .resolver(fused(&m))
            .run()
        {
            Ok(rep) => rep,
            Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
        };
        let plan = rep.residency.as_ref().expect("residency auto must plan");
        if plan.pinned_bytes > plan.budget_bytes || plan.budget_bytes != budget {
            return (
                false,
                format!("pinned {} over budget {}", plan.pinned_bytes, plan.budget_bytes),
            );
        }
        // Per-pin accounting matches the plan total.
        let sum: u64 = plan.pins.iter().map(|p| p.bytes()).sum();
        (sum == plan.pinned_bytes, format!("pin sum {sum} != {}", plan.pinned_bytes))
    });
}

#[test]
fn resident_plan_never_slower_than_pr4_auto_property() {
    // The acceptance invariant: `--residency auto` serves
    // min(PR-4 Auto, resident plan), so it can never lose — on ANY
    // geometry, dense or MoE, under forced splits (reduce tails
    // everywhere) as under the fused resolver.
    let m = machine();
    forall("resident <= PR-4 auto", 4, |rng| {
        let step = random_step(rng);
        if step.layer.validate().is_err() {
            return (false, format!("illegal geometry {:?}", step.layer.geometry));
        }
        for use_fused in [true, false] {
            let run = |mode: ResidencyMode| {
                let sim = StepSim::new(&m, &step).overlap(OverlapMode::Auto).residency(mode);
                if use_fused {
                    sim.resolver(fused(&m)).run()
                } else {
                    sim.resolver(forced_split_resolver(&m)).run()
                }
            };
            let without = match run(ResidencyMode::Off) {
                Ok(rep) => rep,
                Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
            };
            let with = match run(ResidencyMode::Auto) {
                Ok(rep) => rep,
                Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
            };
            if with.served_ns() > without.served_ns() * 1.000001 {
                return (
                    false,
                    format!(
                        "fused={use_fused}: resident {} > PR-4 auto {}",
                        with.served_ns(),
                        without.served_ns()
                    ),
                );
            }
            let plan = with.residency.as_ref().expect("plan present");
            if plan.resident_ns > plan.baseline_ns * 1.000001 {
                return (false, "plan must never beat its own baseline backwards".into());
            }
        }
        (true, String::new())
    });
}

#[test]
fn pinning_conserves_weight_bytes_property() {
    // Byte conservation: the carried trace moves exactly the same read
    // bytes as the cold trace — pinning changes the *service point*
    // (HBM -> L2), never the byte count.
    let m = machine();
    let sim = Simulator::new(m.clone());
    forall("pinning conserves bytes", 10, |rng| {
        let n = 16 * rng.usize_range(1, 256);
        let k = 128 * rng.usize_range(2, 64);
        let batch = rng.usize_range(1, 32);
        let p = GemmProblem::new(batch, n, k);
        let strategy = *rng.choose(&[Strategy::SplitK, Strategy::Chunked, Strategy::Fused]);
        let trace = match kernels::schedule(&m, &p, strategy) {
            Ok(t) => t,
            Err(e) => return (false, format!("{strategy:?} n={n} k={k}: {e}")),
        };
        let carried = carry_weights(&trace);
        let read_total = |t: &ascend_w4a16::ascend::KernelTrace| -> u64 {
            t.phases
                .iter()
                .flat_map(|ph| ph.steps_per_engine.iter().flatten())
                .map(|s| s.read_bytes())
                .sum()
        };
        if read_total(&carried) != read_total(&trace) {
            return (false, format!("{strategy:?} n={n} k={k}: read bytes changed"));
        }
        // And the simulated ledgers agree on totals: cold run vs pinned
        // run move the same bytes, split differently between HBM and L2.
        let cold = sim.run(&trace).unwrap();
        let footprint = residency::weight_footprint_bytes(&p);
        let pinned = sim
            .run_with_residency(&carried, &ResidencyLedger::with_pinned_weights(footprint))
            .unwrap();
        let weight_reads = |r: &ascend_w4a16::ascend::SimReport| -> f64 {
            [BufferClass::WeightPacked, BufferClass::QuantParam, BufferClass::CarriedWeight]
                .iter()
                .map(|&c| {
                    let t = r.ledger.class(c);
                    t.hbm_read + t.l2_read
                })
                .sum()
        };
        let (cw, pw) = (weight_reads(&cold), weight_reads(&pinned));
        if (cw - pw).abs() > 1e-6 {
            return (false, format!("{strategy:?}: weight read bytes {cw} -> {pw}"));
        }
        // The pinned run serves every weight byte from L2.
        let carried_cls = pinned.ledger.class(BufferClass::CarriedWeight);
        (
            carried_cls.hbm_read == 0.0,
            format!("{strategy:?}: pinned weights still read {} from HBM", carried_cls.hbm_read),
        )
    });
}

#[test]
fn residency_composes_with_chain_level_overlap() {
    // Exact + residency on a forced-split dense step: the report carries
    // both machineries and the accounting stays consistent.
    let m = machine();
    let geom = LayerGeometry::mha(2048, 8192);
    let step = DecodeStep::new(DecodeLayer::new(geom, 8), 2048, DecodeStep::default_heads(&geom));
    let rep = StepSim::new(&m, &step)
        .overlap(OverlapMode::Exact)
        .residency(ResidencyMode::Auto)
        .resolver(forced_split_resolver(&m))
        .run()
        .unwrap();
    assert!(rep.exact_ns <= rep.sequential_ns * 1.000001);
    assert!(rep.served_ns() <= rep.exact_ns * 1.000001);
    let plan = rep.residency.as_ref().unwrap();
    assert!(plan.pinned_bytes <= plan.budget_bytes);
    // Accounting balances exactly on the exact side.
    assert!(
        (rep.sequential_ns - rep.exact_gain_ns() - rep.exact_ns).abs() < 1e-6,
        "exact ledger must price every gain exactly once"
    );
}
