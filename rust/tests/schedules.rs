//! Schedule-level integration tests: the paper's headline behaviours as
//! executable assertions, across the whole shape table.

use ascend_w4a16::analysis::layer::{self, OverlapMode};
use ascend_w4a16::analysis::stepsim::StepSim;
use ascend_w4a16::ascend::{BufferClass, MachineConfig, Simulator, Unit};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::model::llm::{
    paper_layer_geometries, paper_moe_geometries, paper_shapes, PAPER_BATCH_SIZES,
};
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{DecodeLayer, DecodeStep};

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

#[test]
fn every_sweep_cell_schedules_and_simulates() {
    let m = machine();
    let sim = Simulator::new(m.clone());
    for shape in paper_shapes() {
        for &batch in &PAPER_BATCH_SIZES {
            let p = GemmProblem::new(batch, shape.n, shape.k);
            for s in Strategy::all_concrete() {
                let trace = kernels::schedule(&m, &p, s)
                    .unwrap_or_else(|e| panic!("{} M={batch} {:?}: {e}", shape.tag(), s));
                let r = sim
                    .run(&trace)
                    .unwrap_or_else(|e| panic!("{} M={batch} {:?}: {e}", shape.tag(), s));
                assert!(r.total_ns > 0.0);
            }
        }
    }
}

#[test]
fn mac_conservation_across_strategies_property() {
    // Every strategy must schedule exactly the padded problem's MACs.
    let m = machine();
    forall("macs conserved", 30, |rng| {
        let shape = paper_shapes()[rng.usize_range(0, 11)];
        let batch = PAPER_BATCH_SIZES[rng.usize_range(0, 6)];
        let p = GemmProblem::new(batch, shape.n, shape.k);
        let want = p.macs(&m);
        for s in Strategy::all_concrete() {
            let t = kernels::schedule(&m, &p, s).unwrap();
            if t.total_macs() != want {
                return (
                    false,
                    format!("{} M={batch} {:?}: {} != {want}", shape.tag(), s, t.total_macs()),
                );
            }
        }
        (true, String::new())
    });
}

#[test]
fn splitk_wins_in_k_dominant_regime() {
    // Paper §4.1: Split-K outperforms DP when K >> N (band 1.01x-1.74x).
    let m = machine();
    let sim = Simulator::new(m.clone());
    for shape in paper_shapes().iter().filter(|s| s.k_dominant()) {
        let p = GemmProblem::new(8, shape.n, shape.k);
        let sk = sim.run(&kernels::schedule(&m, &p, Strategy::SplitK).unwrap()).unwrap();
        let dp = sim.run(&kernels::schedule(&m, &p, Strategy::DataParallel).unwrap()).unwrap();
        let speedup = dp.total_ns / sk.total_ns;
        assert!(
            speedup >= 0.95,
            "{}: Split-K lost badly ({speedup:.3}x)",
            shape.tag()
        );
    }
}

#[test]
fn w4a16_speedup_capped_well_below_4x() {
    // Paper §4.2: max ~1.48x, never approaching the theoretical 4x.
    let m = machine();
    let sim = Simulator::new(m.clone());
    let mut max_speedup: f64 = 0.0;
    for shape in paper_shapes() {
        for &batch in &[1usize, 8, 64] {
            let p = GemmProblem::new(batch, shape.n, shape.k);
            let sk = sim.run(&kernels::schedule(&m, &p, Strategy::SplitK).unwrap()).unwrap();
            let fp = sim.run(&kernels::schedule(&m, &p, Strategy::Fp16Native).unwrap()).unwrap();
            max_speedup = max_speedup.max(fp.total_ns / sk.total_ns);
        }
    }
    assert!(max_speedup < 2.5, "max speedup {max_speedup:.2}x too close to 4x");
    assert!(max_speedup > 1.2, "W4A16 never wins at all ({max_speedup:.2}x)");
}

#[test]
fn execution_time_flat_in_m_below_cube_tile() {
    // Paper: the cube core pads small batches to its tile, so M in
    // {1..16} costs the same.
    let m = machine();
    let sim = Simulator::new(m.clone());
    for strategy in [Strategy::SplitK, Strategy::DataParallel, Strategy::Fp16Native] {
        let times: Vec<f64> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&batch| {
                let p = GemmProblem::new(batch, 2048, 7168);
                sim.run(&kernels::schedule(&m, &p, strategy).unwrap()).unwrap().total_ns
            })
            .collect();
        for w in times.windows(2) {
            assert!(
                (w[0] - w[1]).abs() / w[0] < 0.01,
                "{strategy:?}: {times:?}"
            );
        }
    }
}

#[test]
fn dequant_always_on_vector_mmad_always_on_cube() {
    // The architectural constraint the paper is built around.
    let m = machine();
    for shape in paper_shapes().iter().take(4) {
        let p = GemmProblem::new(8, shape.n, shape.k);
        for s in [Strategy::SplitK, Strategy::DataParallel, Strategy::Chunked] {
            let t = kernels::schedule(&m, &p, s).unwrap();
            for phase in &t.phases {
                match phase.name {
                    "dequant" | "chunk_dequant" | "reduce" | "reduce_stream"
                    | "reduce_tail" => assert_eq!(phase.unit, Unit::Vector),
                    _ => assert_eq!(phase.unit, Unit::Cube, "phase {}", phase.name),
                }
            }
        }
    }
}

#[test]
fn workspace_traffic_only_for_w4a16_strategies() {
    let m = machine();
    let p = GemmProblem::new(8, 2048, 7168);
    let ws_bytes = |s: Strategy| {
        let t = kernels::schedule(&m, &p, s).unwrap();
        t.phases
            .iter()
            .map(|ph| ph.read_bytes(BufferClass::Workspace) + ph.write_bytes(BufferClass::Workspace))
            .sum::<u64>()
    };
    assert!(ws_bytes(Strategy::SplitK) > 0);
    assert!(ws_bytes(Strategy::DataParallel) > 0);
    assert!(ws_bytes(Strategy::Chunked) > 0, "chunked still moves workspace bytes (via L2)");
    assert_eq!(ws_bytes(Strategy::Fp16Native), 0);
    assert_eq!(ws_bytes(Strategy::Fused), 0);
}

#[test]
fn chunked_workspace_hbm_is_zero_on_decode_shapes() {
    // The chunk pipeline's whole point: Workspace-class traffic stays in
    // L2 on the paper's decode shapes — the simulator ledger must show
    // exactly zero HBM bytes for it (acceptance criterion).
    let m = machine();
    let sim = Simulator::new(m.clone());
    for (n, k) in [(512usize, 16384usize), (1536, 7168), (1024, 7680), (2048, 7168)] {
        let p = GemmProblem::new(8, n, k);
        let r = sim.run(&kernels::schedule(&m, &p, Strategy::Chunked).unwrap()).unwrap();
        let ws = r.ledger.class(BufferClass::Workspace);
        assert_eq!(ws.hbm_read, 0.0, "n={n} k={k}");
        assert_eq!(ws.hbm_write, 0.0, "n={n} k={k}");
        assert!(ws.l2_total() > 0.0, "n={n} k={k}");
    }
}

#[test]
fn chunked_at_least_as_fast_as_splitk_in_k_dominant_regime() {
    // Satellite acceptance: chunked >= splitk on EVERY K >> N decode shape
    // of the fig2 sweep, strictly faster somewhere (the spilling shapes).
    let m = machine();
    let sim = Simulator::new(m.clone());
    let mut strict_win = false;
    for shape in paper_shapes().iter().filter(|s| s.k_dominant()) {
        let p = GemmProblem::new(8, shape.n, shape.k);
        let sk = sim.run(&kernels::schedule(&m, &p, Strategy::SplitK).unwrap()).unwrap();
        let ck = sim.run(&kernels::schedule(&m, &p, Strategy::Chunked).unwrap()).unwrap();
        assert!(
            ck.total_ns <= sk.total_ns * 1.000001,
            "{}: chunked {} slower than splitk {}",
            shape.tag(),
            ck.total_ns,
            sk.total_ns
        );
        if ck.total_ns < sk.total_ns * 0.98 {
            strict_win = true;
        }
    }
    assert!(strict_win, "chunked never strictly beat splitk in the K>>N regime");
}

#[test]
fn served_reduce_never_slower_on_every_paper_decode_shape() {
    // Acceptance criterion: the simulator ledger shows the pipelined
    // (served, ReduceMode::Auto) reduce strictly faster or equal — never
    // slower — than the barrier reduce on every paper decode shape, for
    // both Split-K schedules.
    use ascend_w4a16::kernels::ReduceMode;
    let m = machine();
    let sim = Simulator::new(m.clone());
    for shape in paper_shapes() {
        for &batch in &[1usize, 8, 64] {
            let p = GemmProblem::new(batch, shape.n, shape.k);
            for strategy in [Strategy::SplitK, Strategy::Chunked] {
                let t = kernels::select_tiling(&m, &p, strategy).unwrap();
                let served = sim
                    .run(&kernels::schedule_with_reduce(&m, &p, strategy, &t, ReduceMode::Auto)
                        .unwrap())
                    .unwrap()
                    .total_ns;
                let barrier = sim
                    .run(&kernels::schedule_with_reduce(&m, &p, strategy, &t, ReduceMode::Barrier)
                        .unwrap())
                    .unwrap()
                    .total_ns;
                assert!(
                    served <= barrier * 1.000001,
                    "{} M={batch} {strategy:?}: served {served} > barrier {barrier}",
                    shape.tag()
                );
            }
        }
    }
}

#[test]
fn auto_overlap_never_slower_than_sequential_across_paper_models() {
    // Acceptance criterion: the Auto overlap plan is never slower than
    // PR-2's sequential ledger across the paper-shape sweep — every dense
    // trunk and the MoE decoding scenario, at small/medium/large batch.
    let m = machine();
    let mut steps: Vec<(String, DecodeStep)> = Vec::new();
    for (model, geom) in paper_layer_geometries() {
        for batch in [1usize, 8, 64] {
            let layer = DecodeLayer::new(geom, batch);
            steps.push((
                format!("{model} b={batch}"),
                DecodeStep::new(layer, 2048, DecodeStep::default_heads(&geom)),
            ));
        }
    }
    for (model, geom, moe) in paper_moe_geometries() {
        for batch in [1usize, 8, 64] {
            let layer = DecodeLayer::new(geom, batch).with_moe(moe);
            steps.push((
                format!("{model} b={batch}"),
                DecodeStep::new(layer, 2048, DecodeStep::default_heads(&geom)),
            ));
        }
    }
    let mut some_gain = false;
    for (tag, step) in steps {
        // Force a K split where legal so every node carries a reduce
        // phase: the never-slower guarantee must hold for ANY tiling,
        // and the wide-N heuristic alone would pick S = 1 everywhere
        // (no reduce, nothing to overlap — a vacuous sweep).
        let rep = StepSim::new(&m, &step)
            .overlap(OverlapMode::Auto)
            .resolver(layer::forced_split_resolver(&m))
            .run()
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
        assert!(
            rep.served_ns() <= rep.sequential_ns * 1.000001,
            "{tag}: served {} slower than sequential {}",
            rep.served_ns(),
            rep.sequential_ns
        );
        assert!(rep.sequential_ns.is_finite() && rep.sequential_ns > 0.0, "{tag}");
        // The step covers attention + glue, not just GEMMs.
        assert!(rep.vector_ns() > 0.0, "{tag}: non-GEMM nodes missing");
        some_gain |= rep.overlap_gain_ns() > 0.0;
    }
    assert!(
        some_gain,
        "the overlap ledger never found a reduce/dequant pair across the whole sweep"
    );
}

#[test]
fn residency_auto_never_slower_than_pr4_auto_across_paper_sweep() {
    // PR-5 acceptance criterion: on the full paper-model decode-step
    // sweep (the e2e_layer bench's tuned cells), `--residency auto` is
    // never slower than PR-4 `--overlap auto` on ANY shape, and strictly
    // faster on at least one K >> N decode shape — the regime the paper
    // targets, where the tuned (fused) winners are HBM-bound on the
    // packed-weight stream and pinning moves it onto L2.
    use ascend_w4a16::analysis::residency::ResidencyMode;
    let m = machine();
    let mut tuner = ascend_w4a16::tune::Tuner::new(m.clone());
    let mut steps: Vec<(String, DecodeStep, bool)> = Vec::new();
    for (model, geom) in paper_layer_geometries() {
        for batch in [1usize, 8, 64] {
            let layer = DecodeLayer::new(geom, batch);
            let k_dominant =
                layer.gemm_nodes().iter().any(|n| n.problem.k >= 2 * n.problem.n);
            steps.push((
                format!("{model} b={batch}"),
                DecodeStep::new(layer, 2048, DecodeStep::default_heads(&geom)),
                k_dominant,
            ));
        }
    }
    for (model, geom, moe) in paper_moe_geometries() {
        for batch in [1usize, 8, 64] {
            let layer = DecodeLayer::new(geom, batch).with_moe(moe);
            let k_dominant =
                layer.gemm_nodes().iter().any(|n| n.problem.k >= 2 * n.problem.n);
            steps.push((
                format!("{model} b={batch}"),
                DecodeStep::new(layer, 2048, DecodeStep::default_heads(&geom)),
                k_dominant,
            ));
        }
    }
    let mut strict_k_dominant_win = false;
    for (tag, step, k_dominant) in &steps {
        let without = StepSim::new(&m, step)
            .overlap(OverlapMode::Auto)
            .tuner(&mut tuner)
            .run()
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
        let with = StepSim::new(&m, step)
            .overlap(OverlapMode::Auto)
            .residency(ResidencyMode::Auto)
            .tuner(&mut tuner)
            .run()
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
        assert!(
            with.served_ns() <= without.served_ns() * 1.000001,
            "{tag}: residency auto {} slower than PR-4 auto {}",
            with.served_ns(),
            without.served_ns()
        );
        let plan = with.residency.as_ref().unwrap_or_else(|| panic!("{tag}: plan missing"));
        assert!(
            plan.pinned_bytes <= plan.budget_bytes,
            "{tag}: pinned {} over budget {}",
            plan.pinned_bytes,
            plan.budget_bytes
        );
        if *k_dominant && with.served_ns() < without.served_ns() * 0.999999 {
            strict_k_dominant_win = true;
        }
    }
    assert!(
        strict_k_dominant_win,
        "the resident plan never strictly beat PR-4 Auto on any K>>N decode shape"
    );
}

#[test]
fn fused_strictly_dominates_splitk_property() {
    let m = machine();
    let sim = Simulator::new(m.clone());
    forall("fused < splitk", 20, |rng| {
        let shape = paper_shapes()[rng.usize_range(0, 11)];
        let batch = PAPER_BATCH_SIZES[rng.usize_range(0, 6)];
        let p = GemmProblem::new(batch, shape.n, shape.k);
        let sk = sim.run(&kernels::schedule(&m, &p, Strategy::SplitK).unwrap()).unwrap();
        let fu = sim.run(&kernels::schedule(&m, &p, Strategy::Fused).unwrap()).unwrap();
        (
            fu.total_ns <= sk.total_ns,
            format!("{} M={batch}: fused {} vs sk {}", shape.tag(), fu.total_ns, sk.total_ns),
        )
    });
}
