//! Cross-cutting property tests on coordinator invariants (routing,
//! batching, request state) — the proptest deliverable for L3 — plus the
//! pipelined-reduce, tune-cache and cross-node overlap-ledger invariants
//! of DESIGN.md §10–§11.

use ascend_w4a16::analysis::layer::{OverlapMode, Resolution, StepNodeReport};
use ascend_w4a16::analysis::stepsim::StepSim;
use ascend_w4a16::coordinator::{BatchPolicy, Batcher, DecodeRequest};
use ascend_w4a16::kernels::tiling::Tiling;
use ascend_w4a16::kernels::{self, chunked, splitk, GemmProblem, ReduceMode, Strategy};
use ascend_w4a16::ascend::{BufferClass, MachineConfig, Simulator};
use ascend_w4a16::model::llm::{LayerGeometry, MoeGeometry};
use ascend_w4a16::tune::{machine_tag, shape_key, TuneCache, TunedEntry, Tuner};
use ascend_w4a16::util::json::Json;
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{DecodeLayer, DecodeStep};

#[test]
fn batcher_never_loses_or_duplicates_requests() {
    forall("batcher conservation", 60, |rng| {
        let sizes: Vec<usize> = match rng.usize_range(0, 2) {
            0 => vec![1, 2, 4],
            1 => vec![1, 2, 4, 8],
            _ => vec![4],
        };
        let mut b = Batcher::new(BatchPolicy::new(sizes).unwrap());
        let n = rng.usize_range(1, 40);
        for id in 0..n as u64 {
            b.push(DecodeRequest::new(id, vec![1, 2], 4), 0);
        }
        let mut seen = std::collections::BTreeSet::new();
        while let Some(g) = b.form_group(true, 0) {
            if g.occupancy() == 0 || g.occupancy() > g.batch {
                return (false, format!("bad group occupancy {}", g.occupancy()));
            }
            for m in &g.members {
                if !seen.insert(m.id) {
                    return (false, format!("duplicate id {}", m.id));
                }
            }
        }
        (seen.len() == n, format!("saw {} of {n}", seen.len()))
    });
}

#[test]
fn batcher_groups_fit_available_sizes() {
    forall("group size legal", 60, |rng| {
        let sizes = vec![1, 2, 4, 8];
        let mut b = Batcher::new(BatchPolicy::new(sizes.clone()).unwrap());
        let n = rng.usize_range(1, 30);
        for id in 0..n as u64 {
            b.push(DecodeRequest::new(id, vec![1], 2), 0);
        }
        while let Some(g) = b.form_group(true, 0) {
            if !sizes.contains(&g.batch) {
                return (false, format!("illegal batch {}", g.batch));
            }
            if g.occupancy() > g.batch {
                return (false, "overfull".into());
            }
        }
        (true, String::new())
    });
}

#[test]
fn request_validation_total_order() {
    forall("validation is consistent", 60, |rng| {
        let prompt_len = rng.usize_range(1, 20);
        let budget = rng.usize_range(1, 20);
        let max_seq = rng.usize_range(4, 40);
        let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.usize_range(0, 255) as i32).collect();
        let r = DecodeRequest::new(0, prompt, budget);
        let valid = r.validate(256, max_seq).is_ok();
        let expected = prompt_len + budget <= max_seq;
        (valid == expected, format!("len={prompt_len} budget={budget} max={max_seq}"))
    });
}

#[test]
fn tiling_validates_for_random_legal_problems() {
    let m = MachineConfig::ascend910();
    forall("tiler total", 60, |rng| {
        let n = 16 * rng.usize_range(1, 512);
        let k = 128 * rng.usize_range(1, 128);
        let batch = rng.usize_range(1, 64);
        let p = GemmProblem::new(batch, n, k);
        match kernels::tiling::select_splitk(&m, &p) {
            Ok(t) => (t.validate(&m, &p).is_ok(), format!("n={n} k={k}")),
            Err(e) => (false, format!("n={n} k={k}: {e}")),
        }
    });
}

#[test]
fn chunked_tiler_total_and_mac_conserving() {
    // The chunked tiler must produce a legal tiling for every legal
    // problem, and the resulting schedule must conserve MACs exactly.
    let m = MachineConfig::ascend910();
    forall("chunked tiler total", 40, |rng| {
        let n = 16 * rng.usize_range(1, 512);
        let k = 128 * rng.usize_range(1, 128);
        let batch = rng.usize_range(1, 64);
        let p = GemmProblem::new(batch, n, k);
        let t = match kernels::tiling::select_chunked(&m, &p) {
            Ok(t) => t,
            Err(e) => return (false, format!("n={n} k={k}: {e}")),
        };
        if t.validate(&m, &p).is_err() {
            return (false, format!("n={n} k={k}: illegal tiling {t:?}"));
        }
        match kernels::schedule(&m, &p, Strategy::Chunked) {
            Ok(trace) => (
                trace.total_macs() == p.macs(&m),
                format!("n={n} k={k} C={}: {} != {}", t.chunks, trace.total_macs(), p.macs(&m)),
            ),
            Err(e) => (false, format!("n={n} k={k}: {e}")),
        }
    });
}

#[test]
fn chunked_never_loses_to_splitk_property() {
    // The chunked selector falls back to monolithic pinning, so across
    // random shapes it can tie but never meaningfully lose to Algorithm 1.
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("chunked <= splitk", 25, |rng| {
        let n = 16 * rng.usize_range(1, 256);
        let k = 128 * rng.usize_range(1, 64);
        let p = GemmProblem::new(8, n, k);
        let sk = sim
            .run(&kernels::schedule(&m, &p, Strategy::SplitK).unwrap())
            .unwrap()
            .total_ns;
        let ck = sim
            .run(&kernels::schedule(&m, &p, Strategy::Chunked).unwrap())
            .unwrap()
            .total_ns;
        // The chunked selector simulates its candidates and degenerates to
        // Algorithm 1 (identical trace) when chunking doesn't pay, so it
        // can tie but never lose beyond float noise.
        (ck <= sk * 1.000001, format!("n={n} k={k}: chunked {ck} vs splitk {sk}"))
    });
}

#[test]
fn simulated_time_strictly_positive_and_finite() {
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("finite time", 40, |rng| {
        let n = 16 * rng.usize_range(1, 256);
        let k = 128 * rng.usize_range(1, 64);
        let p = GemmProblem::new(rng.usize_range(1, 64), n, k);
        let strategy = *rng.choose(&[
            Strategy::SplitK,
            Strategy::DataParallel,
            Strategy::Fp16Native,
            Strategy::Fused,
            Strategy::Chunked,
        ]);
        match kernels::schedule(&m, &p, strategy).and_then(|t| sim.run(&t)) {
            Ok(r) => (
                r.total_ns.is_finite() && r.total_ns > 0.0,
                format!("n={n} k={k} {strategy:?} t={}", r.total_ns),
            ),
            Err(e) => (false, format!("n={n} k={k} {strategy:?}: {e}")),
        }
    });
}

#[test]
fn pipelined_reduce_reduces_every_output_tile_exactly_once() {
    // Schedule-level invariants of the reduce pipelining: every output
    // tile reduced exactly once (so the FP16 output is written exactly
    // once), chunk indices never rewind (the simulator's validator), and
    // the phase split loses no tiles.
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("reduce covers tiles once", 40, |rng| {
        let n = 16 * rng.usize_range(1, 512);
        let k = 128 * rng.usize_range(1, 96);
        let batch = rng.usize_range(1, 64);
        let p = GemmProblem::new(batch, n, k);
        let splitk_t = kernels::tiling::select_splitk(&m, &p).unwrap();
        let chunked_t = kernels::tiling::select_chunked(&m, &p).unwrap();
        let traces = [
            splitk::schedule_reduce(&m, &p, &splitk_t, ReduceMode::Pipelined).unwrap(),
            chunked::schedule_reduce(&m, &p, &chunked_t, ReduceMode::Pipelined).unwrap(),
        ];
        for (trace, t) in traces.iter().zip([&splitk_t, &chunked_t]) {
            if let Err(e) = sim.validate(trace) {
                return (false, format!("n={n} k={k} {}: {e}", trace.name));
            }
            let out: u64 = trace
                .phases
                .iter()
                .map(|ph| ph.write_bytes(BufferClass::Output))
                .sum();
            let want = (p.m_padded(&m) * n * 2) as u64;
            if out != want {
                return (false, format!("n={n} k={k} {}: output {out} != {want}", trace.name));
            }
            if t.splits > 1 {
                let reduce_steps: usize = trace
                    .phases
                    .iter()
                    .filter(|ph| ph.name.starts_with("reduce"))
                    .map(|ph| ph.total_steps())
                    .sum();
                let out_tiles = (p.m_padded(&m) / t.bm) * (n / t.bn);
                if reduce_steps != out_tiles {
                    return (
                        false,
                        format!("n={n} k={k} {}: {reduce_steps} != {out_tiles}", trace.name),
                    );
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn served_reduce_never_slower_than_barrier_reduce() {
    // The served schedule (ReduceMode::Auto) picks the faster of the
    // pipelined and barrier reduces, so across a randomized shape sweep it
    // can tie but never lose to Algorithm 1's barrier reduce.
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("pipelined reduce <= barrier", 30, |rng| {
        let n = 16 * rng.usize_range(1, 512);
        let k = 128 * rng.usize_range(1, 96);
        let batch = rng.usize_range(1, 64);
        let p = GemmProblem::new(batch, n, k);
        for strategy in [Strategy::SplitK, Strategy::Chunked] {
            let t = kernels::select_tiling(&m, &p, strategy).unwrap();
            let served = sim
                .run(&kernels::schedule_with_reduce(&m, &p, strategy, &t, ReduceMode::Auto).unwrap())
                .unwrap()
                .total_ns;
            let barrier = sim
                .run(&kernels::schedule_with_reduce(&m, &p, strategy, &t, ReduceMode::Barrier).unwrap())
                .unwrap()
                .total_ns;
            if served > barrier * 1.000001 {
                return (
                    false,
                    format!("n={n} k={k} {strategy:?}: served {served} > barrier {barrier}"),
                );
            }
        }
        (true, String::new())
    });
}

#[test]
fn uneven_tile_counts_stream_their_floor_wave() {
    // ROADMAP PR-2 follow-up: when output tiles do NOT divide evenly over
    // the vector engines, the floor-wave still streams (each engine keeps
    // exactly one tail tile) and the served (Auto) schedule is never
    // slower than the barrier reduce.
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    let engines = m.total_vector_cores();
    forall("uneven floor-wave streams", 25, |rng| {
        // bn = 16 gives out_tiles = (m_pad/16) * (n/16): sample a tile
        // count in [130, 380) so every draw clears the two-wave gate and
        // most draws are uneven.
        let n_tiles = rng.usize_range(130, 380);
        let n = 16 * n_tiles;
        let k = 128 * rng.usize_range(2, 24);
        let splits = 2usize;
        if (k / splits) % 128 != 0 {
            return (true, String::new());
        }
        let batch = rng.usize_range(1, 16); // m_pad = 16 -> one m-tile row
        let p = GemmProblem::new(batch, n, k);
        let t = Tiling {
            bm: 16,
            bn: 16,
            bk: 128,
            splits,
            chunks: 1,
            dequant_bk: 128,
            dequant_bn: 16,
            rebalance: 0,
        };
        if t.validate(&m, &p).is_err() {
            return (false, format!("n={n} k={k}: tiling must be legal"));
        }
        let out_tiles = (p.m_padded(&m) / t.bm) * (p.n / t.bn);
        assert!(out_tiles >= 2 * engines);
        let tr = splitk::schedule_reduce(&m, &p, &t, ReduceMode::Pipelined).unwrap();
        let names: Vec<&str> = tr.phases.iter().map(|ph| ph.name).collect();
        if names != vec!["dequant", "splitk_mmad", "reduce_stream", "reduce_tail"] {
            return (false, format!("n={n} k={k}: phases {names:?}"));
        }
        let stream = &tr.phases[2];
        let tail = &tr.phases[3];
        if stream.total_steps() != out_tiles - engines || tail.total_steps() != engines {
            return (
                false,
                format!(
                    "n={n} k={k}: stream {} + tail {} != {out_tiles} tiles",
                    stream.total_steps(),
                    tail.total_steps()
                ),
            );
        }
        // Every engine keeps exactly one tail tile; stream counts differ
        // by at most one (ceil vs floor wave).
        let lens: Vec<usize> =
            stream.steps_per_engine.iter().map(|s| s.len()).collect();
        let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        if hi - lo > 1 {
            return (false, format!("n={n} k={k}: stream imbalance {lo}..{hi}"));
        }
        if out_tiles % engines != 0 && hi == lo {
            return (false, format!("n={n} k={k}: uneven count must split waves"));
        }
        // Every output tile reduced exactly once.
        let out: u64 = tr.phases[2..]
            .iter()
            .map(|ph| ph.write_bytes(BufferClass::Output))
            .sum();
        if out != (p.m_padded(&m) * p.n * 2) as u64 {
            return (false, format!("n={n} k={k}: output bytes {out}"));
        }
        // served (Auto) <= barrier, even though the uneven stream has no
        // construction-level proof: Auto simulates both and keeps the winner.
        let served = sim
            .run(&kernels::schedule_with_reduce(&m, &p, Strategy::SplitK, &t, ReduceMode::Auto)
                .unwrap())
            .unwrap()
            .total_ns;
        let barrier = sim
            .run(&kernels::schedule_with_reduce(
                &m,
                &p,
                Strategy::SplitK,
                &t,
                ReduceMode::Barrier,
            )
            .unwrap())
            .unwrap()
            .total_ns;
        (
            served <= barrier * 1.000001,
            format!("n={n} k={k}: served {served} > barrier {barrier}"),
        )
    });
}

/// Random legal decoder-layer geometry (group-aligned widths), sometimes
/// with a routed expert fan-out.
fn random_step(rng: &mut ascend_w4a16::util::prng::Rng) -> DecodeStep {
    let hidden = 128 * rng.usize_range(2, 24);
    let ffn = 128 * rng.usize_range(2, 32);
    let kv = 16 * rng.usize_range(1, hidden / 16);
    let geometry = LayerGeometry { hidden, ffn, kv, group: 128 };
    let batch = rng.usize_range(1, 64);
    let mut layer = DecodeLayer::new(geometry, batch);
    if rng.usize_range(0, 1) == 1 {
        let experts = *rng.choose(&[4usize, 8, 64]);
        let topk = (*rng.choose(&[1usize, 2])).min(experts);
        layer = layer.with_moe(MoeGeometry { experts, topk, expert_ffn: ffn });
    }
    let kv_len = 128 * rng.usize_range(1, 32);
    DecodeStep::new(layer, kv_len, DecodeStep::default_heads(&geometry))
}

#[test]
fn overlap_ledger_prices_each_node_once_and_never_double_books() {
    // DESIGN.md §11 invariants: (a) the overlapped total equals the
    // sequential total minus every ledger gain — each node's reduce and
    // dequant priced exactly once; (b) no pair hides more vector work
    // than the consumer's idle vector headroom (no engine double-booked
    // in the same tick) nor more than the producer's exposed reduce; (c)
    // each GEMM acts as producer at most once and consumer at most once.
    let m = MachineConfig::ascend910();
    forall("overlap ledger balances", 12, |rng| {
        let step = random_step(rng);
        if step.layer.validate().is_err() {
            return (false, format!("illegal geometry {:?}", step.layer.geometry));
        }
        let strategy = *rng.choose(&[Strategy::SplitK, Strategy::Chunked]);
        let force_split = rng.usize_range(0, 1) == 1;
        let rep = match StepSim::new(&m, &step)
            .overlap(OverlapMode::Auto)
            .resolver(|p| {
                let mut t = kernels::select_tiling(&m, p, strategy)?;
                // Half the cases force a K split so nodes carry a reduce
                // phase and the ledger is non-trivially exercised.
                if force_split {
                    let split = Tiling { splits: t.splits.max(2), ..t };
                    if split.validate(&m, p).is_ok() {
                        t = split;
                    }
                }
                Ok((strategy, t, Resolution::Heuristic))
            })
            .run()
        {
            Ok(rep) => rep,
            Err(e) => return (false, format!("{:?}: {e}", step.layer.geometry)),
        };
        let gain: f64 = rep.ledger.iter().map(|p| p.total_gain_ns()).sum();
        if (rep.sequential_ns - gain - rep.overlapped_ns).abs() > 1e-6 {
            return (false, format!("ledger does not balance: {gain}"));
        }
        let mut producers = std::collections::BTreeSet::new();
        let mut consumers = std::collections::BTreeSet::new();
        for pair in &rep.ledger {
            if pair.gain_ns > pair.reduce_ns + 1e-9 || pair.gain_ns > pair.slack_ns + 1e-9 {
                return (
                    false,
                    format!(
                        "pair {}->{} double-books: gain {} reduce {} slack {}",
                        pair.producer, pair.consumer, pair.gain_ns, pair.reduce_ns, pair.slack_ns
                    ),
                );
            }
            // An entry exists when one of the pricings found a positive
            // gain: the first-order ledger term, the co-scheduler's exact
            // merged-trace term, or (PR 5) a chain-level decision — all
            // clamped non-negative.
            let exact_gain = pair.exact.map(|d| d.gain_ns).unwrap_or(0.0);
            let chain_gain = pair.chain.map(|c| c.decision.gain_ns).unwrap_or(0.0);
            if (pair.gain_ns <= 0.0 && exact_gain <= 0.0 && chain_gain <= 0.0)
                || pair.pairs == 0
            {
                return (false, "ledger must only carry positive gains".into());
            }
            if exact_gain < 0.0 || chain_gain < 0.0 {
                return (false, "co-schedule gains are clamped non-negative".into());
            }
            let internal = pair.producer == pair.consumer;
            if !internal && !producers.insert(pair.producer) {
                return (false, format!("node {} produces twice", pair.producer));
            }
            if !internal && !consumers.insert(pair.consumer) {
                return (false, format!("node {} consumes twice", pair.consumer));
            }
            match &rep.nodes[pair.producer] {
                StepNodeReport::Gemm(g) => {
                    if internal && pair.pairs != g.count - 1 {
                        return (
                            false,
                            format!("internal pairs {} != count-1 {}", pair.pairs, g.count - 1),
                        );
                    }
                }
                StepNodeReport::Vector(_) => {
                    return (false, "vector nodes cannot join the ledger".into())
                }
            }
        }
        // Auto is never slower than the sequential chain.
        (
            rep.served_ns() <= rep.sequential_ns * 1.000001,
            format!("served {} > sequential {}", rep.served_ns(), rep.sequential_ns),
        )
    });
}

#[test]
fn tune_cache_round_trips_identical_lookups() {
    // serialize -> deserialize -> every key resolves to the identical entry.
    forall("tune cache round trip", 40, |rng| {
        let mut cache = TuneCache::new();
        let mut keys = Vec::new();
        for i in 0..rng.usize_range(1, 12) {
            let entry = TunedEntry {
                strategy: *rng.choose(&Strategy::all_concrete()),
                total_ns: rng.usize_range(1, 1 << 30) as f64,
                tiling: Tiling {
                    bm: 16 << rng.usize_range(0, 3),
                    bn: 16 << rng.usize_range(0, 4),
                    bk: 16 << rng.usize_range(0, 3),
                    splits: 1 << rng.usize_range(0, 5),
                    chunks: 1 << rng.usize_range(0, 6),
                    dequant_bk: 128,
                    dequant_bn: 16 << rng.usize_range(0, 4),
                    rebalance: 0,
                },
            };
            let key = format!("machine{}/m16_n{}_k{}_g128", i % 3, 16 * (i + 1), 128 * (i + 1));
            cache.insert(key.clone(), entry);
            keys.push((key, entry));
        }
        let json = cache.to_json().to_string();
        let back = TuneCache::from_json(&Json::parse(&json).unwrap()).unwrap();
        if back.len() != cache.len() {
            return (false, format!("{} entries became {}", cache.len(), back.len()));
        }
        for (key, entry) in &keys {
            if back.get(key) != Some(entry) {
                return (false, format!("lookup '{key}' changed across the round trip"));
            }
        }
        (true, String::new())
    });
}

#[test]
fn tune_cache_never_serves_another_machines_entry() {
    // Staleness: an entry keyed to a different machine tag is never
    // returned, even for the identical GEMM shape.
    let machine = MachineConfig::ascend910();
    let mut other = MachineConfig::ascend910();
    other.ai_cores = 24; // different architecture -> different tag
    assert_ne!(machine_tag(&machine), machine_tag(&other));

    let p = GemmProblem::new(8, 512, 16384);
    let entry = TunedEntry {
        strategy: Strategy::Chunked,
        total_ns: 123.0,
        tiling: kernels::tiling::select_chunked(&machine, &p).unwrap(),
    };
    let mut tuner = Tuner::new(machine.clone());
    tuner.cache.insert(shape_key(&other, &p), entry);
    assert!(
        tuner.lookup(&p).is_none(),
        "stale entry from another machine must not be served"
    );
    // The same entry under the current machine's key IS served.
    tuner.cache.insert(shape_key(&machine, &p), entry);
    assert_eq!(tuner.lookup(&p), Some(entry));
}

#[test]
fn splitk_time_monotone_in_problem_size() {
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("monotone in K", 30, |rng| {
        let n = 16 * rng.usize_range(4, 128);
        let kg = rng.usize_range(1, 32);
        let p1 = GemmProblem::new(8, n, 128 * kg);
        let p2 = GemmProblem::new(8, n, 128 * (kg + rng.usize_range(1, 32)));
        let t1 = sim
            .run(&kernels::schedule(&m, &p1, Strategy::SplitK).unwrap())
            .unwrap()
            .total_ns;
        let t2 = sim
            .run(&kernels::schedule(&m, &p2, Strategy::SplitK).unwrap())
            .unwrap()
            .total_ns;
        (t2 >= t1 * 0.999, format!("n={n} k1={} k2={}", p1.k, p2.k))
    });
}
