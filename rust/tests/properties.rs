//! Cross-cutting property tests on coordinator invariants (routing,
//! batching, request state) — the proptest deliverable for L3.

use ascend_w4a16::coordinator::{BatchPolicy, Batcher, DecodeRequest};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::util::proptest::forall;

#[test]
fn batcher_never_loses_or_duplicates_requests() {
    forall("batcher conservation", 60, |rng| {
        let sizes: Vec<usize> = match rng.usize_range(0, 2) {
            0 => vec![1, 2, 4],
            1 => vec![1, 2, 4, 8],
            _ => vec![4],
        };
        let mut b = Batcher::new(BatchPolicy::new(sizes).unwrap());
        let n = rng.usize_range(1, 40);
        for id in 0..n as u64 {
            b.push(DecodeRequest::new(id, vec![1, 2], 4));
        }
        let mut seen = std::collections::BTreeSet::new();
        while let Some(g) = b.form_group(true) {
            if g.occupancy() == 0 || g.occupancy() > g.batch {
                return (false, format!("bad group occupancy {}", g.occupancy()));
            }
            for m in &g.members {
                if !seen.insert(m.id) {
                    return (false, format!("duplicate id {}", m.id));
                }
            }
        }
        (seen.len() == n, format!("saw {} of {n}", seen.len()))
    });
}

#[test]
fn batcher_groups_fit_available_sizes() {
    forall("group size legal", 60, |rng| {
        let sizes = vec![1, 2, 4, 8];
        let mut b = Batcher::new(BatchPolicy::new(sizes.clone()).unwrap());
        let n = rng.usize_range(1, 30);
        for id in 0..n as u64 {
            b.push(DecodeRequest::new(id, vec![1], 2));
        }
        while let Some(g) = b.form_group(true) {
            if !sizes.contains(&g.batch) {
                return (false, format!("illegal batch {}", g.batch));
            }
            if g.occupancy() > g.batch {
                return (false, "overfull".into());
            }
        }
        (true, String::new())
    });
}

#[test]
fn request_validation_total_order() {
    forall("validation is consistent", 60, |rng| {
        let prompt_len = rng.usize_range(1, 20);
        let budget = rng.usize_range(1, 20);
        let max_seq = rng.usize_range(4, 40);
        let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.usize_range(0, 255) as i32).collect();
        let r = DecodeRequest::new(0, prompt, budget);
        let valid = r.validate(256, max_seq).is_ok();
        let expected = prompt_len + budget <= max_seq;
        (valid == expected, format!("len={prompt_len} budget={budget} max={max_seq}"))
    });
}

#[test]
fn tiling_validates_for_random_legal_problems() {
    let m = MachineConfig::ascend910();
    forall("tiler total", 60, |rng| {
        let n = 16 * rng.usize_range(1, 512);
        let k = 128 * rng.usize_range(1, 128);
        let batch = rng.usize_range(1, 64);
        let p = GemmProblem::new(batch, n, k);
        match kernels::tiling::select_splitk(&m, &p) {
            Ok(t) => (t.validate(&m, &p).is_ok(), format!("n={n} k={k}")),
            Err(e) => (false, format!("n={n} k={k}: {e}")),
        }
    });
}

#[test]
fn chunked_tiler_total_and_mac_conserving() {
    // The chunked tiler must produce a legal tiling for every legal
    // problem, and the resulting schedule must conserve MACs exactly.
    let m = MachineConfig::ascend910();
    forall("chunked tiler total", 40, |rng| {
        let n = 16 * rng.usize_range(1, 512);
        let k = 128 * rng.usize_range(1, 128);
        let batch = rng.usize_range(1, 64);
        let p = GemmProblem::new(batch, n, k);
        let t = match kernels::tiling::select_chunked(&m, &p) {
            Ok(t) => t,
            Err(e) => return (false, format!("n={n} k={k}: {e}")),
        };
        if t.validate(&m, &p).is_err() {
            return (false, format!("n={n} k={k}: illegal tiling {t:?}"));
        }
        match kernels::schedule(&m, &p, Strategy::Chunked) {
            Ok(trace) => (
                trace.total_macs() == p.macs(&m),
                format!("n={n} k={k} C={}: {} != {}", t.chunks, trace.total_macs(), p.macs(&m)),
            ),
            Err(e) => (false, format!("n={n} k={k}: {e}")),
        }
    });
}

#[test]
fn chunked_never_loses_to_splitk_property() {
    // The chunked selector falls back to monolithic pinning, so across
    // random shapes it can tie but never meaningfully lose to Algorithm 1.
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("chunked <= splitk", 25, |rng| {
        let n = 16 * rng.usize_range(1, 256);
        let k = 128 * rng.usize_range(1, 64);
        let p = GemmProblem::new(8, n, k);
        let sk = sim
            .run(&kernels::schedule(&m, &p, Strategy::SplitK).unwrap())
            .unwrap()
            .total_ns;
        let ck = sim
            .run(&kernels::schedule(&m, &p, Strategy::Chunked).unwrap())
            .unwrap()
            .total_ns;
        // The chunked selector simulates its candidates and degenerates to
        // Algorithm 1 (identical trace) when chunking doesn't pay, so it
        // can tie but never lose beyond float noise.
        (ck <= sk * 1.000001, format!("n={n} k={k}: chunked {ck} vs splitk {sk}"))
    });
}

#[test]
fn simulated_time_strictly_positive_and_finite() {
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("finite time", 40, |rng| {
        let n = 16 * rng.usize_range(1, 256);
        let k = 128 * rng.usize_range(1, 64);
        let p = GemmProblem::new(rng.usize_range(1, 64), n, k);
        let strategy = *rng.choose(&[
            Strategy::SplitK,
            Strategy::DataParallel,
            Strategy::Fp16Native,
            Strategy::Fused,
            Strategy::Chunked,
        ]);
        match kernels::schedule(&m, &p, strategy).and_then(|t| sim.run(&t)) {
            Ok(r) => (
                r.total_ns.is_finite() && r.total_ns > 0.0,
                format!("n={n} k={k} {strategy:?} t={}", r.total_ns),
            ),
            Err(e) => (false, format!("n={n} k={k} {strategy:?}: {e}")),
        }
    });
}

#[test]
fn splitk_time_monotone_in_problem_size() {
    let m = MachineConfig::ascend910();
    let sim = Simulator::new(m.clone());
    forall("monotone in K", 30, |rng| {
        let n = 16 * rng.usize_range(4, 128);
        let kg = rng.usize_range(1, 32);
        let p1 = GemmProblem::new(8, n, 128 * kg);
        let p2 = GemmProblem::new(8, n, 128 * (kg + rng.usize_range(1, 32)));
        let t1 = sim
            .run(&kernels::schedule(&m, &p1, Strategy::SplitK).unwrap())
            .unwrap()
            .total_ns;
        let t2 = sim
            .run(&kernels::schedule(&m, &p2, Strategy::SplitK).unwrap())
            .unwrap()
            .total_ns;
        (t2 >= t1 * 0.999, format!("n={n} k1={} k2={}", p1.k, p2.k))
    });
}
