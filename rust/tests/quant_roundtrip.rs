//! Cross-language quantization contract: the rust quantizer must agree
//! with the python one bit-for-bit on packing layout and within rounding
//! on values.  The python side's conventions are frozen in the manifest
//! artifacts, so these tests also guard the rust<->artifact boundary.

use ascend_w4a16::quant::{self, QuantizedWeight};
use ascend_w4a16::tensor::MatF32;
use ascend_w4a16::util::prng::Rng;
use ascend_w4a16::util::proptest::forall;

fn random_weight(k: usize, n: usize, seed: u64) -> MatF32 {
    let mut rng = Rng::new(seed);
    MatF32::from_vec(k, n, rng.normal_vec(k * n, 0.05))
}

#[test]
fn packing_layout_matches_python_convention() {
    // python: byte = (q[2k+1] << 4) | q[2k]; int8 storage.
    let codes: Vec<u8> = vec![0x3, 0xA, 0xF, 0x0];
    let packed = quant::pack_int4(&codes, 4, 1).unwrap();
    assert_eq!(packed, vec![(0xA << 4 | 0x3) as i8, 0x0F]);
}

#[test]
fn dequantize_reconstructs_within_half_step_property() {
    forall("quant error bound", 40, |rng| {
        let kg = rng.usize_range(1, 4);
        let n = rng.usize_range(1, 24);
        let k = kg * 128;
        let w = random_weight(k, n, rng.next_u64());
        let qw = quant::quantize_groupwise(&w, 128, false).unwrap();
        let back = qw.dequantize();
        for kk in 0..k {
            for nn in 0..n {
                let s = qw.scales[(kk / 128) * n + nn];
                if (w.at(kk, nn) - back.at(kk, nn)).abs() > s * 0.5 + 1e-6 {
                    return (false, format!("k={kk} n={nn}"));
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn symmetric_roundtrip_error_property() {
    forall("symmetric quant bound", 40, |rng| {
        let k = 128 * rng.usize_range(1, 3);
        let n = rng.usize_range(1, 16);
        let w = random_weight(k, n, rng.next_u64());
        let qw = quant::quantize_groupwise(&w, 128, true).unwrap();
        let back = qw.dequantize();
        for kk in 0..k {
            for nn in 0..n {
                let s = qw.scales[(kk / 128) * n + nn];
                // symmetric clamps at code 0: allow a full step
                if (w.at(kk, nn) - back.at(kk, nn)).abs() > s * 1.0 + 1e-6 {
                    return (false, format!("k={kk} n={nn}"));
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn w4a16_reference_is_close_to_full_precision() {
    let a = random_weight(16, 256, 1); // reuse as activations
    let w = random_weight(256, 64, 2);
    let qw = quant::quantize_groupwise(&w, 128, false).unwrap();
    let quantized = quant::w4a16_reference(&a, &qw);
    let full = a.matmul(&w);
    // 4-bit weights: expect small but nonzero degradation.
    let diff = quantized.max_abs_diff(&full);
    assert!(diff > 0.0, "quantization should not be exact on random data");
    assert!(diff < 0.5, "quantization error too large: {diff}");
}

#[test]
fn compression_ratio_is_exactly_4x() {
    forall("4x compression", 20, |rng| {
        let k = 128 * rng.usize_range(1, 6);
        let n = 16 * rng.usize_range(1, 8);
        let qw = quant::quantize_groupwise(&random_weight(k, n, rng.next_u64()), 128, false)
            .unwrap();
        (qw.packed_bytes() * 4 == k * n * 2, format!("k={k} n={n}"))
    });
}

#[test]
fn unpack_is_left_inverse_of_pack_property() {
    forall("pack/unpack", 60, |rng| {
        let k = 2 * rng.usize_range(1, 64);
        let n = rng.usize_range(1, 32);
        let codes: Vec<u8> = (0..k * n).map(|_| (rng.next_u64() % 16) as u8).collect();
        let packed = quant::pack_int4(&codes, k, n).unwrap();
        let back = quant::unpack_int4(&packed, k, n).unwrap();
        (back == codes, format!("k={k} n={n}"))
    });
}

#[test]
fn quantized_weight_accessors() {
    let qw: QuantizedWeight =
        quant::quantize_groupwise(&random_weight(256, 8, 3), 128, false).unwrap();
    assert_eq!(qw.groups(), 2);
    assert_eq!(qw.packed.len(), 128 * 8);
    assert_eq!(qw.scales.len(), 2 * 8);
}
