//! Integration tests over the util substrates (JSON/CLI/f16/PRNG/stats)
//! plus property tests with the mini-proptest kit.

use ascend_w4a16::util::f16;
use ascend_w4a16::util::json::Json;
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::util::stats::{geomean, Summary};

#[test]
fn json_parses_manifest_like_document() {
    let doc = r#"{
        "version": 1,
        "artifacts": [
            {"name": "a", "inputs": [{"name": "x", "dtype": "f32", "shape": [2, 3]}]}
        ],
        "batch_sizes": [1, 2, 4],
        "group": 128
    }"#;
    let j = Json::parse(doc).unwrap();
    assert_eq!(j.req_usize("group").unwrap(), 128);
    let arts = j.req_arr("artifacts").unwrap();
    assert_eq!(arts[0].req_str("name").unwrap(), "a");
    let spec = &arts[0].req_arr("inputs").unwrap()[0];
    assert_eq!(spec.req_arr("shape").unwrap().len(), 2);
}

#[test]
fn json_serialization_is_reparseable_property() {
    forall("json round trip", 100, |rng| {
        // build a random small document
        let mut pairs = Vec::new();
        let n = rng.usize_range(0, 5);
        for i in 0..n {
            let v = match rng.usize_range(0, 3) {
                0 => Json::num(rng.f64() * 1000.0 - 500.0),
                1 => Json::str(format!("value-{}\"quoted\"", rng.next_u64() % 100)),
                2 => Json::Bool(rng.next_u64() % 2 == 0),
                _ => Json::Null,
            };
            pairs.push((format!("key{i}"), v));
        }
        let doc = Json::Obj(pairs.into_iter().collect());
        let text = doc.to_string();
        let ok = match Json::parse(&text) {
            Ok(back) => {
                // numeric equality within f64 print precision
                format!("{back}") == text
            }
            Err(_) => false,
        };
        (ok, text)
    });
}

#[test]
fn f16_round_trip_preserves_order_property() {
    forall("f16 rounding is monotone", 300, |rng| {
        let a = rng.f32_range(-1000.0, 1000.0);
        let b = rng.f32_range(-1000.0, 1000.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let ok = f16::round_to_f16(lo) <= f16::round_to_f16(hi);
        (ok, format!("lo={lo} hi={hi}"))
    });
}

#[test]
fn f16_error_bounded_by_half_ulp_property() {
    forall("f16 relative error < 2^-11", 300, |rng| {
        let x = rng.f32_range(-60000.0, 60000.0);
        let r = f16::round_to_f16(x);
        let tol = x.abs().max(6.1e-5) * 4.9e-4; // 2^-11 relative
        let ok = (x - r).abs() <= tol;
        (ok, format!("x={x} r={r}"))
    });
}

#[test]
fn summary_is_translation_equivariant_property() {
    forall("summary translation", 50, |rng| {
        let n = rng.usize_range(2, 30);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 100.0).collect();
        let shift = 42.0;
        let shifted: Vec<f64> = xs.iter().map(|x| x + shift).collect();
        let s0 = Summary::of(&xs);
        let s1 = Summary::of(&shifted);
        let ok = (s1.mean - s0.mean - shift).abs() < 1e-9
            && (s1.stddev - s0.stddev).abs() < 1e-9
            && (s1.p50 - s0.p50 - shift).abs() < 1e-9;
        (ok, format!("n={n}"))
    });
}

#[test]
fn geomean_of_reciprocals_inverts() {
    let xs = [1.5, 2.0, 0.8];
    let inv: Vec<f64> = xs.iter().map(|x| 1.0 / x).collect();
    assert!((geomean(&xs) * geomean(&inv) - 1.0).abs() < 1e-12);
}
