//! Full-stack end-to-end test: the ~100M-parameter decode model served
//! through the coordinator, plus engine-level decode semantics.
//! (Requires `make artifacts`; skips politely otherwise.)

use ascend_w4a16::coordinator::{BatchPolicy, Batcher, Router, Server};
use ascend_w4a16::model::{DecodeEngine, Engine};
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::workload::RequestGenerator;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn manifest() -> Option<Manifest> {
    if !std::path::Path::new(ARTIFACTS).join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(ARTIFACTS).unwrap())
}

#[test]
fn tiny_engine_multi_step_decode_advances_state() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut engine = DecodeEngine::new(&rt, mf.decode("tiny", 1).unwrap()).unwrap();
    let mut token = 3i32;
    let mut produced = Vec::new();
    for pos in 0..6 {
        let out = engine.step(&[token], &[pos]).unwrap();
        token = out.next_tokens[0];
        produced.push(token);
    }
    assert_eq!(engine.steps_taken(), 6);
    assert!(produced.iter().all(|&t| t >= 0 && (t as usize) < engine.vocab));
    // A non-trivial model should not emit a constant stream.
    assert!(produced.windows(2).any(|w| w[0] != w[1]), "{produced:?}");
}

#[test]
fn engine_reset_restores_initial_behaviour() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut engine = DecodeEngine::new(&rt, mf.decode("tiny", 1).unwrap()).unwrap();
    let a = engine.step(&[9], &[0]).unwrap().next_tokens.clone();
    engine.step(&[a[0]], &[1]).unwrap();
    engine.reset().unwrap();
    let b = engine.step(&[9], &[0]).unwrap().next_tokens.clone();
    assert_eq!(a, b, "reset must clear the KV cache");
}

#[test]
fn engine_rejects_bad_arity_and_positions() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut engine = DecodeEngine::new(&rt, mf.decode("tiny", 4).unwrap()).unwrap();
    assert!(engine.step(&[1], &[0]).is_err(), "arity");
    let max = engine.max_seq as i32;
    assert!(engine.step(&[1, 1, 1, 1], &[max, 0, 0, 0]).is_err(), "position bound");
}

/// The headline E2E: serve batched requests against the ~100M model and
/// verify the serving stack end to end.  One group of batch<=2 keeps the
/// CPU wallclock reasonable.
#[test]
fn small100m_serves_batched_requests() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let router = Router::new(&rt, mf, "small100m").unwrap();
    let sizes: Vec<usize> = router.batch_sizes().into_iter().filter(|&b| b <= 2).collect();
    assert!(!sizes.is_empty());
    let mut server = Server::new(router, Batcher::new(BatchPolicy::new(sizes).unwrap()));

    let (vocab, max_seq) = {
        let e = server.router.engine(1).unwrap();
        match e {
            Engine::Real(d) => assert!(d.hidden == 768 && d.layers == 12, "100M geometry"),
            Engine::Synthetic(_) => panic!("weighted artifact must build a real engine"),
        }
        (e.vocab(), e.max_seq())
    };
    let mut generator = RequestGenerator::new(11, vocab, max_seq.min(24));
    for mut req in generator.burst(2) {
        req.max_new_tokens = req.max_new_tokens.min(4);
        server.submit(req);
    }
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.iter().all(|&t| t >= 0 && (t as usize) < vocab));
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests_completed, 2);
    assert!(snap.steps_executed > 0);
}
