//! Analysis-layer integration: the §4.2 decomposition and the figure
//! renderers over real simulated kernels.

use ascend_w4a16::analysis::{report, roofline, traffic};
use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::util::json::Json;

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

fn simulate(p: &GemmProblem, s: Strategy) -> ascend_w4a16::ascend::SimReport {
    let m = machine();
    Simulator::new(m.clone())
        .run(&kernels::schedule(&m, p, s).unwrap())
        .unwrap()
}

#[test]
fn fig2_sweep_produces_all_cells_and_summary_bands() {
    let cells = report::fig2_sweep(&machine()).unwrap();
    assert_eq!(cells.len(), 12 * 7);
    // Headline: Split-K wins in the K>>N regime.
    let kd: Vec<f64> = cells
        .iter()
        .filter(|c| c.k >= 2 * c.n)
        .map(|c| c.speedup())
        .collect();
    assert!(!kd.is_empty());
    let geomean = ascend_w4a16::util::stats::geomean(&kd);
    assert!(
        (1.05..2.2).contains(&geomean),
        "K>>N geomean speedup {geomean:.2} outside plausible band"
    );
    let max = kd.iter().cloned().fold(0.0f64, f64::max);
    assert!(max >= 1.3, "no strong Split-K win found (max {max:.2})");
}

#[test]
fn fig3_sweep_reproduces_the_cap() {
    let cells = report::fig3_sweep(&machine()).unwrap();
    assert_eq!(cells.len(), 12 * 7);
    let max = cells.iter().map(|c| c.speedup()).fold(0.0f64, f64::max);
    // Paper: at most ~1.48x; our simulator must stay well below 4x and
    // above 1.2x at the best shape.
    assert!((1.2..2.2).contains(&max), "max W4A16 speedup {max:.2}");
    // And some oversized-workspace shapes must lose (spill regime).
    let min = cells.iter().map(|c| c.speedup()).fold(f64::INFINITY, f64::min);
    assert!(min < 1.0, "spill regime should drop below 1x (min {min:.2})");
}

#[test]
fn bottleneck_is_transfer_not_cast_across_the_sweep() {
    // §4.2's claim, verified over every K>>N shape.
    let m = machine();
    for shape in ascend_w4a16::model::llm::paper_shapes() {
        let p = GemmProblem::new(8, shape.n, shape.k);
        let r = simulate(&p, Strategy::SplitK);
        let b = traffic::decompose(&r);
        assert!(
            b.transfer_bound,
            "{}: cast {} vs transfer {}",
            shape.tag(),
            b.cast_compute_ns,
            b.transfer_ns
        );
    }
}

#[test]
fn round_trip_ratio_is_8x_packed() {
    let r = simulate(&GemmProblem::new(8, 2048, 7168), Strategy::SplitK);
    let b = traffic::decompose(&r);
    assert!((b.round_trip_ratio - 8.0).abs() < 0.5, "{}", b.round_trip_ratio);
}

#[test]
fn roofline_efficiency_reasonable_for_all_strategies() {
    let m = machine();
    let p = GemmProblem::new(8, 2048, 7168);
    for s in [Strategy::SplitK, Strategy::DataParallel, Strategy::Fp16Native, Strategy::Fused] {
        let r = simulate(&p, s);
        let pt = roofline::place(&m, &r);
        assert!(pt.memory_bound, "{s:?} should be memory-bound at decode shapes");
        assert!(
            (0.2..=1.0).contains(&pt.efficiency),
            "{s:?} efficiency {}",
            pt.efficiency
        );
    }
}

#[test]
fn renderers_emit_paper_comparisons() {
    let m = machine();
    let fig2 = report::render_fig2(&report::fig2_sweep(&m).unwrap());
    assert!(fig2.contains("paper: 1.01x-1.74x"));
    let fig3 = report::render_fig3(&report::fig3_sweep(&m).unwrap());
    assert!(fig3.contains("at most 1.48x"));
}

#[test]
fn json_outputs_parse_and_cover_sweep() {
    let m = machine();
    let j = report::fig3_json(&report::fig3_sweep(&m).unwrap()).to_string();
    let parsed = Json::parse(&j).unwrap();
    assert_eq!(parsed.as_arr().unwrap().len(), 84);
    let first = &parsed.as_arr().unwrap()[0];
    assert!(first.get("speedup").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn fused_ceiling_approaches_4x_when_l2_resident() {
    let m = machine();
    let r = simulate(&GemmProblem::new(8, 2048, 7168), Strategy::SplitK);
    let ceiling = traffic::theoretical_speedup_ceiling(&m, &r);
    // With the workspace resident in L2, almost no HBM round trip remains:
    // the *traffic* ceiling approaches 4x even though the *time* cap is
    // ~1.5x (L2 bandwidth is finite) — exactly the paper's distinction.
    assert!(ceiling > 3.0, "ceiling {ceiling}");
}
