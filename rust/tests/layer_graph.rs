//! Decode-layer GEMM-graph integration: the graph simulator over every
//! paper model, and the coordinator router resolving all four projection
//! GEMMs through the tune cache (exercised against a synthetic manifest,
//! so it runs without artifacts or PJRT).

use ascend_w4a16::analysis::layer;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::coordinator::{Metrics, Router, Server};
use ascend_w4a16::kernels::Strategy;
use ascend_w4a16::model::llm::paper_layer_geometries;
use ascend_w4a16::runtime::artifacts::DecodeConfig;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::workload::{DecodeLayer, GemmKind};

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

#[test]
fn every_paper_model_layer_simulates_with_tuned_nodes() {
    // Acceptance: a full decode layer with all four GEMMs resolved through
    // the tuner, served reduce never slower than the barrier reduce.
    let m = machine();
    let mut tuner = Tuner::new(m.clone());
    for (model, geom) in paper_layer_geometries() {
        for batch in [1usize, 8, 64] {
            let decode_layer = DecodeLayer::new(geom, batch);
            let rep = layer::simulate_layer_tuned(&m, &decode_layer, &mut tuner)
                .unwrap_or_else(|e| panic!("{model} b={batch}: {e}"));
            assert_eq!(rep.nodes.len(), 4, "{model} b={batch}");
            for n in &rep.nodes {
                assert!(n.total_ns > 0.0 && n.total_ns.is_finite());
                assert!(
                    n.total_ns <= n.barrier_ns * 1.000001,
                    "{model} b={batch} {}: served {} > barrier {}",
                    n.kind.name(),
                    n.total_ns,
                    n.barrier_ns
                );
            }
            assert!(
                rep.layer_ns() <= rep.layer_barrier_ns() * 1.000001,
                "{model} b={batch}: layer served slower than barrier"
            );
        }
    }
}

#[test]
fn tuned_layer_beats_all_splitk_layer() {
    // Per-node strategy selection is the point of the graph: the tuned
    // layer can tie but never lose to serving every node under the
    // heuristic splitk schedule.
    let m = machine();
    let mut tuner = Tuner::new(m.clone());
    for (model, geom) in paper_layer_geometries() {
        let decode_layer = DecodeLayer::new(geom, 8);
        let tuned = layer::simulate_layer_tuned(&m, &decode_layer, &mut tuner).unwrap();
        let splitk = layer::simulate_layer(&m, &decode_layer, |p| {
            Ok((
                Strategy::SplitK,
                ascend_w4a16::kernels::select_tiling(&m, p, Strategy::SplitK)?,
                layer::Resolution::Heuristic,
            ))
        })
        .unwrap();
        assert!(
            tuned.layer_ns() <= splitk.layer_ns() * 1.000001,
            "{model}: tuned layer {} slower than splitk layer {}",
            tuned.layer_ns(),
            splitk.layer_ns()
        );
    }
}

// ---------------------------------------------------------------------------
// Router wiring against a synthetic manifest (no artifacts, no PJRT).
// ---------------------------------------------------------------------------

fn tiny_config() -> DecodeConfig {
    DecodeConfig {
        vocab: 512,
        hidden: 256,
        layers: 2,
        heads: 4,
        ffn: 1024,
        max_seq: 64,
        group: 128,
        params: 0,
    }
}

/// Write a minimal manifest (one decode artifact) + a warmed tune cache
/// into a fresh temp dir.
fn synthetic_artifacts(tag: &str, warm_cache: bool) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-layer-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = r#"{
  "group": 128,
  "batch_sizes": [4],
  "paper_shapes": [],
  "artifacts": [
    {
      "name": "decode_tiny_b4",
      "kind": "decode",
      "path": "decode_tiny_b4.hlo.txt",
      "model": "tiny",
      "batch": 4,
      "config": {"vocab": 512, "hidden": 256, "layers": 2, "heads": 4,
                 "ffn": 1024, "max_seq": 64, "group": 128, "params": 0},
      "inputs": [],
      "outputs": []
    }
  ]
}"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    if warm_cache {
        let mut tuner = Tuner::new(machine());
        let decode_layer = DecodeLayer::from_decode_config(&tiny_config(), 4);
        for (_, p) in decode_layer.problems() {
            tuner.resolve(&p).unwrap();
        }
        tuner.save_to(dir.join("tune_cache.json")).unwrap();
    }
    dir
}

#[test]
fn router_resolves_all_four_gemms_through_the_cache() {
    let dir = synthetic_artifacts("warm", true);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(router.has_tune_cache());

    let plan = router.layer_plan(4).expect("decode config present");
    assert!(
        plan.fully_resolved(),
        "all four projection GEMMs must resolve cache-only: {plan:?}"
    );
    assert!(plan.predicted_layer_ns().unwrap() > 0.0);
    // The headline (down-projection) plan matches the layer plan's node.
    let down = router.tuned_plan(4).unwrap();
    assert_eq!(Some(down), plan.get(GemmKind::Down));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn routed_batch_records_all_four_gemm_kinds() {
    // Regression (metrics): after one routed decode batch, every GEMM kind
    // appears in the per-GEMM schedule counters.
    let dir = synthetic_artifacts("metrics", true);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    let plan = router.layer_plan(4);

    let metrics = Metrics::new();
    Server::record_group_schedules(&metrics, plan.as_ref());
    let snap = metrics.snapshot();
    for kind in GemmKind::all() {
        let counts = snap
            .gemm_schedules
            .get(kind.name())
            .unwrap_or_else(|| panic!("kind '{}' missing after a routed batch", kind.name()));
        assert_eq!(counts.values().map(|st| st.groups).sum::<u64>(), 1);
        assert!(
            !counts.contains_key("untuned"),
            "{}: warmed cache must resolve, got {counts:?}",
            kind.name()
        );
        // Tuned nodes surface their predicted kernel latency.
        assert!(
            counts.values().all(|st| st.mean_predicted_us() > 0.0),
            "{}: predicted latency missing, got {counts:?}",
            kind.name()
        );
    }
    assert_eq!(snap.schedules.values().sum::<u64>(), 1, "headline counter");
    let rendered = snap.render(1.0);
    for kind in GemmKind::all() {
        assert!(rendered.contains(kind.name()), "render missing {}", kind.name());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cold_cache_serves_untuned_but_still_covers_all_kinds() {
    let dir = synthetic_artifacts("cold", false);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(!router.has_tune_cache());
    assert!(router.layer_plan(4).is_none(), "no cache file -> no plan");

    let metrics = Metrics::new();
    Server::record_group_schedules(&metrics, None);
    let snap = metrics.snapshot();
    for kind in GemmKind::all() {
        assert_eq!(snap.gemm_schedules[kind.name()]["untuned"].groups, 1);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
