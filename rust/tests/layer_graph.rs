//! Decode-layer GEMM-graph integration: the graph simulator over every
//! paper model (dense and MoE), and the coordinator router resolving
//! every GEMM node — the dense projections or the routed expert fan-out —
//! through the tune cache (exercised against synthetic manifests, so it
//! runs without artifacts or PJRT).

use ascend_w4a16::analysis::layer;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::coordinator::{Metrics, RouteReason, RouteRung, Router, Server};
use ascend_w4a16::kernels::Strategy;
use ascend_w4a16::model::llm::paper_layer_geometries;
use ascend_w4a16::runtime::artifacts::DecodeConfig;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::workload::{DecodeLayer, GemmKind};

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

#[test]
fn every_paper_model_layer_simulates_with_tuned_nodes() {
    // Acceptance: a full decode layer with all four GEMMs resolved through
    // the tuner, served reduce never slower than the barrier reduce.
    let m = machine();
    let mut tuner = Tuner::new(m.clone());
    for (model, geom) in paper_layer_geometries() {
        for batch in [1usize, 8, 64] {
            let decode_layer = DecodeLayer::new(geom, batch);
            let rep = layer::simulate_layer_tuned(&m, &decode_layer, &mut tuner)
                .unwrap_or_else(|e| panic!("{model} b={batch}: {e}"));
            assert_eq!(rep.nodes.len(), 4, "{model} b={batch}");
            for n in &rep.nodes {
                assert!(n.total_ns > 0.0 && n.total_ns.is_finite());
                assert!(
                    n.total_ns <= n.barrier_ns * 1.000001,
                    "{model} b={batch} {}: served {} > barrier {}",
                    n.kind.name(),
                    n.total_ns,
                    n.barrier_ns
                );
            }
            assert!(
                rep.layer_ns() <= rep.layer_barrier_ns() * 1.000001,
                "{model} b={batch}: layer served slower than barrier"
            );
        }
    }
}

#[test]
fn tuned_layer_beats_all_splitk_layer() {
    // Per-node strategy selection is the point of the graph: the tuned
    // layer can tie but never lose to serving every node under the
    // heuristic splitk schedule.
    let m = machine();
    let mut tuner = Tuner::new(m.clone());
    for (model, geom) in paper_layer_geometries() {
        let decode_layer = DecodeLayer::new(geom, 8);
        let tuned = layer::simulate_layer_tuned(&m, &decode_layer, &mut tuner).unwrap();
        let splitk = layer::simulate_layer(&m, &decode_layer, |p| {
            Ok((
                Strategy::SplitK,
                ascend_w4a16::kernels::select_tiling(&m, p, Strategy::SplitK)?,
                layer::Resolution::Heuristic,
            ))
        })
        .unwrap();
        assert!(
            tuned.layer_ns() <= splitk.layer_ns() * 1.000001,
            "{model}: tuned layer {} slower than splitk layer {}",
            tuned.layer_ns(),
            splitk.layer_ns()
        );
    }
}

// ---------------------------------------------------------------------------
// Router wiring against a synthetic manifest (no artifacts, no PJRT).
// ---------------------------------------------------------------------------

fn tiny_config() -> DecodeConfig {
    DecodeConfig {
        vocab: 512,
        hidden: 256,
        layers: 2,
        heads: 4,
        ffn: 1024,
        max_seq: 64,
        group: 128,
        params: 0,
        moe_experts: 0,
        moe_topk: 0,
    }
}

/// The tiny model with its FFN routed over 4 experts (top-2): the MoE
/// serving scenario with no artifacts or PJRT anywhere.
fn tiny_moe_config() -> DecodeConfig {
    DecodeConfig { moe_experts: 4, moe_topk: 2, ..tiny_config() }
}

/// Write a minimal manifest (one decode artifact) + a warmed tune cache
/// into a fresh temp dir.  `moe` routes the tiny model's FFN over
/// experts (via the manifest's optional `moe_experts`/`moe_topk` keys).
fn synthetic_artifacts(tag: &str, warm_cache: bool, moe: bool) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-layer-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let moe_keys = if moe { r#""moe_experts": 4, "moe_topk": 2, "# } else { "" };
    let manifest = format!(
        r#"{{
  "group": 128,
  "batch_sizes": [4],
  "paper_shapes": [],
  "artifacts": [
    {{
      "name": "decode_tiny_b4",
      "kind": "decode",
      "path": "decode_tiny_b4.hlo.txt",
      "model": "tiny",
      "batch": 4,
      "config": {{{moe_keys}"vocab": 512, "hidden": 256, "layers": 2, "heads": 4,
                 "ffn": 1024, "max_seq": 64, "group": 128, "params": 0}},
      "inputs": [],
      "outputs": []
    }}
  ]
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    if warm_cache {
        let mut tuner = Tuner::new(machine());
        let cfg = if moe { tiny_moe_config() } else { tiny_config() };
        let decode_layer = DecodeLayer::from_decode_config(&cfg, 4);
        let nodes = decode_layer.gemm_nodes();
        for node in &nodes {
            tuner.resolve(&node.problem).unwrap();
        }
        // Seed the co-schedule pair decisions too (what `repro tune`
        // does — same `overlap_pairs` enumeration the router looks up),
        // so the router resolves the overlap cache-only.
        for pair in decode_layer.overlap_pairs() {
            tuner.resolve_overlap(&pair.producer, &pair.consumer).unwrap();
        }
        // And the step-level residency plan (DESIGN.md §13), also what
        // `repro tune` seeds, so the router's residency column resolves
        // cache-only.
        tuner.resolve_residency(&decode_layer).unwrap();
        tuner.save_to(dir.join("tune_cache.json")).unwrap();
    }
    dir
}

#[test]
fn router_resolves_all_four_gemms_through_the_cache() {
    let dir = synthetic_artifacts("warm", true, false);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(router.has_tune_cache());

    let plan = router.layer_plan(4).expect("decode config present");
    assert!(
        plan.fully_resolved(),
        "all four projection GEMMs must resolve cache-only: {plan:?}"
    );
    assert!(plan.predicted_layer_ns().unwrap() > 0.0);
    // The headline (down-projection) plan matches the layer plan's node.
    let down = router.tuned_plan(4).unwrap();
    assert_eq!(Some(down), plan.get(GemmKind::Down));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn routed_batch_records_all_four_gemm_kinds() {
    // Regression (metrics): after one routed decode batch, every GEMM kind
    // appears in the per-GEMM schedule counters.
    let dir = synthetic_artifacts("metrics", true, false);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    let plan = router.layer_plan(4);

    let metrics = Metrics::new();
    Server::record_group_schedules(&metrics, plan.as_ref());
    let snap = metrics.snapshot();
    for kind in GemmKind::all() {
        let counts = snap
            .gemm_schedules
            .get(kind.name())
            .unwrap_or_else(|| panic!("kind '{}' missing after a routed batch", kind.name()));
        assert_eq!(counts.values().map(|st| st.groups).sum::<u64>(), 1);
        assert!(
            !counts.contains_key("untuned"),
            "{}: warmed cache must resolve, got {counts:?}",
            kind.name()
        );
        // Tuned nodes surface their predicted kernel latency.
        assert!(
            counts.values().all(|st| st.mean_predicted_us() > 0.0),
            "{}: predicted latency missing, got {counts:?}",
            kind.name()
        );
    }
    assert_eq!(snap.schedules.values().sum::<u64>(), 1, "headline counter");
    let rendered = snap.render(1.0);
    for kind in GemmKind::all() {
        assert!(rendered.contains(kind.name()), "render missing {}", kind.name());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn moe_manifest_resolves_expert_gemms_cache_only() {
    // Satellite acceptance: a synthetic MoE manifest (no artifacts/PJRT)
    // through Router::layer_plan resolves the expert GEMMs cache-only and
    // they appear in the metrics snapshot with their fan-out counts.
    let dir = synthetic_artifacts("moe", true, true);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(router.has_tune_cache());

    let plan = router.layer_plan(4).expect("decode config present");
    assert!(
        plan.fully_resolved(),
        "attention + expert GEMMs must resolve cache-only: {plan:?}"
    );
    let experts: Vec<_> =
        plan.nodes.iter().filter(|n| n.kind == GemmKind::MoeExpert).collect();
    assert_eq!(experts.len(), 2, "expert up/gate + down nodes: {plan:?}");
    for node in &experts {
        // b=4 top-2 over 4 experts: all 4 experts fire, 2 tokens each.
        assert_eq!(node.count, 4);
        assert!(node.plan.unwrap().predicted_ns > 0.0);
    }
    assert!(plan.get(GemmKind::Down).is_none(), "MoE layers have no dense down node");
    // The headline (bottleneck) plan is the expert down-projection.
    let headline = router.tuned_plan(4).unwrap();
    assert_eq!(Some(headline), experts.last().unwrap().plan);
    assert!(plan.predicted_layer_ns().unwrap() > 0.0);

    let metrics = Metrics::new();
    Server::record_group_schedules(&metrics, router.layer_plan(4).as_ref());
    let snap = metrics.snapshot();
    let moe_stats = snap
        .gemm_schedules
        .get("moe_expert")
        .expect("moe_expert kind missing from the snapshot");
    assert_eq!(moe_stats.values().map(|st| st.groups).sum::<u64>(), 2);
    assert_eq!(
        moe_stats.values().map(|st| st.gemms).sum::<u64>(),
        8,
        "per-kind expert counts: 2 nodes x 4 active experts"
    );
    assert!(!moe_stats.contains_key("untuned"), "warmed cache must resolve: {moe_stats:?}");
    let rendered = snap.render(1.0);
    assert!(rendered.contains("moe_expert"), "render missing moe_expert:\n{rendered}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn moe_layer_plan_predicts_full_fanout_latency() {
    // The plan's layer prediction multiplies each expert node by its
    // fan-out, so it matches the graph simulator's sequential GEMM total.
    let dir = synthetic_artifacts("moe-pred", true, true);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    let plan = router.layer_plan(4).unwrap();
    let predicted = plan.predicted_layer_ns().unwrap();
    let per_node: f64 = plan
        .nodes
        .iter()
        .map(|n| n.plan.unwrap().predicted_ns * n.count as f64)
        .sum();
    assert!((predicted - per_node).abs() < 1e-9);
    let dense_dir = synthetic_artifacts("dense-pred", true, false);
    let dense_mf = Manifest::load(&dense_dir).unwrap();
    let mut dense_router = Router::new(&rt, dense_mf, "tiny").unwrap();
    let dense = dense_router.layer_plan(4).unwrap().predicted_layer_ns().unwrap();
    assert!(
        predicted > dense,
        "8 expert GEMMs must out-cost the dense FFN pair ({predicted} vs {dense})"
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dense_dir);
}

#[test]
fn layer_plan_resolves_coschedule_gain_cache_only() {
    // Satellite acceptance: the co-schedule decision per adjacent pair is
    // cached by `repro tune` (mirrored by the synthetic warm cache), so
    // `Router::layer_plan` resolves the overlap gain without ever paying
    // a merged-trace simulation on the serving path.
    for moe in [false, true] {
        let dir = synthetic_artifacts(if moe { "ov-moe" } else { "ov" }, true, moe);
        let rt = Runtime::cpu().unwrap();
        let mf = Manifest::load(&dir).unwrap();
        let mut router = Router::new(&rt, mf, "tiny").unwrap();
        let plan = router.layer_plan(4).expect("decode config present");
        let gain = plan
            .overlap_gain_ns
            .unwrap_or_else(|| panic!("moe={moe}: every pair must hit the cache: {plan:?}"));
        assert!(gain >= 0.0 && gain.is_finite());
        assert!(
            plan.predicted_overlapped_ns().unwrap() <= plan.predicted_layer_ns().unwrap(),
            "overlap can only shrink the predicted layer time"
        );
        // The step-level residency plan resolves cache-only too.
        let res_gain = plan
            .residency_gain_ns
            .unwrap_or_else(|| panic!("moe={moe}: residency plan must hit the cache: {plan:?}"));
        assert!(res_gain >= 0.0 && res_gain.is_finite());
        assert!(plan.residency_pinned_bytes.is_some());
        assert!(
            plan.predicted_resident_ns().unwrap() <= plan.predicted_overlapped_ns().unwrap(),
            "residency can only shrink the predicted layer time further"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
    // A cache with shape entries but no pair decisions (a pre-PR-4 cache)
    // leaves the plan served but unpredicted for overlap and residency.
    let dir = synthetic_artifacts("ov-stale", false, false);
    let mut tuner = Tuner::new(machine());
    for node in DecodeLayer::from_decode_config(&tiny_config(), 4).gemm_nodes() {
        tuner.resolve(&node.problem).unwrap();
    }
    tuner.save_to(dir.join("tune_cache.json")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    let plan = router.layer_plan(4).expect("decode config present");
    assert!(plan.fully_resolved(), "shape entries still resolve");
    assert_eq!(plan.overlap_gain_ns, None, "missing pair decisions must not be invented");
    assert_eq!(plan.residency_gain_ns, None, "missing residency plans must not be invented");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cold_cache_retunes_inline_and_still_covers_all_kinds() {
    // DESIGN.md §14 ladder: a missing cache file no longer serves untuned
    // nodes — the router re-tunes inline under its budget (rung 3), so
    // the plan fully resolves and the outcome names the ladder rung.
    let dir = synthetic_artifacts("cold", false, false);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    assert!(!router.has_tune_cache());
    let routed = router.route(4);
    assert_eq!(routed.outcome.rung, RouteRung::Retuned);
    assert_eq!(routed.outcome.reason, RouteReason::NoCacheFile);
    assert!(routed.outcome.retuned_nodes > 0);
    assert_eq!(routed.outcome.defaulted_nodes, 0);
    let plan = routed.plan.expect("decode config present");
    assert!(plan.fully_resolved(), "inline re-tunes must resolve every node: {plan:?}");
    assert!(router.tuned_plan(4).is_some());

    let metrics = Metrics::new();
    Server::record_group_schedules(&metrics, Some(&plan));
    let snap = metrics.snapshot();
    for kind in GemmKind::all() {
        let counts = &snap.gemm_schedules[kind.name()];
        assert_eq!(counts.values().map(|st| st.groups).sum::<u64>(), 1);
        assert!(!counts.contains_key("untuned"), "{}: {counts:?}", kind.name());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn exhausted_retune_budget_falls_to_priced_splitk_default() {
    // Rung 4 of the ladder: with the inline re-tune budget forced to 0
    // every miss serves the safe splitk default — still priced by the
    // simulator, and never faster than a tuned winner for the same shape.
    let dir = synthetic_artifacts("cold-b0", false, false);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    router.set_retune_budget(0);
    let routed = router.route(4);
    assert_eq!(routed.outcome.rung, RouteRung::DefaultSplitk);
    assert_eq!(routed.outcome.reason, RouteReason::NoCacheFile);
    assert_eq!(routed.outcome.retuned_nodes, 0);
    assert!(routed.outcome.defaulted_nodes > 0);
    let default_plan = routed.plan.expect("decode config present");
    assert!(default_plan.fully_resolved(), "splitk default must price every node");
    for node in &default_plan.nodes {
        assert_eq!(node.plan.unwrap().strategy, Strategy::SplitK);
    }

    // Never-worse ladder: the retuned plan (budget restored) serves each
    // node at most as slow as the splitk default rung below it.
    let mut tuned_router =
        Router::new(&rt, Manifest::load(&dir).unwrap(), "tiny").unwrap();
    let tuned_plan = tuned_router.route(4).plan.unwrap();
    for (tuned, dflt) in tuned_plan.nodes.iter().zip(&default_plan.nodes) {
        assert!(
            tuned.plan.unwrap().predicted_ns <= dflt.plan.unwrap().predicted_ns * 1.000001,
            "{:?}: retuned {} slower than splitk default {}",
            tuned.kind,
            tuned.plan.unwrap().predicted_ns,
            dflt.plan.unwrap().predicted_ns
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cold_cache_moe_metrics_name_the_expert_nodes() {
    // The finding this guards: a MoE manifest with no tune cache must
    // surface `moe_expert` (with its fan-out), not phantom dense nodes.
    let dir = synthetic_artifacts("cold-moe", false, true);
    let rt = Runtime::cpu().unwrap();
    let mf = Manifest::load(&dir).unwrap();
    let mut router = Router::new(&rt, mf, "tiny").unwrap();
    let plan = router.layer_plan(4).expect("decode config present");
    assert!(plan.fully_resolved(), "the ladder resolves MoE nodes too");

    let metrics = Metrics::new();
    Server::record_group_schedules(&metrics, Some(&plan));
    let snap = metrics.snapshot();
    let moe_stats = &snap.gemm_schedules["moe_expert"];
    assert_eq!(moe_stats.values().map(|st| st.groups).sum::<u64>(), 2);
    assert_eq!(
        moe_stats.values().map(|st| st.gemms).sum::<u64>(),
        8,
        "per-kind expert counts: 2 nodes x 4 active experts"
    );
    assert!(
        !snap.gemm_schedules.contains_key("up_gate")
            && !snap.gemm_schedules.contains_key("down"),
        "MoE layers must not record phantom dense FFN nodes"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
