//! Coordinator integration: the serving stack against the real tiny decode
//! artifact (requires `make artifacts`; skips politely otherwise).

use ascend_w4a16::coordinator::{BatchPolicy, Batcher, DecodeRequest, Outcome, Router, Server};
use ascend_w4a16::runtime::{Manifest, Runtime};

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn setup(rt: &Runtime) -> Option<Server<'_>> {
    if !std::path::Path::new(ARTIFACTS).join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let mf = Manifest::load(ARTIFACTS).unwrap();
    let router = Router::new(rt, mf, "tiny").unwrap();
    let sizes = router.batch_sizes();
    Some(Server::new(router, Batcher::new(BatchPolicy::new(sizes).unwrap())))
}

#[test]
fn serves_a_single_request() {
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    server.submit(DecodeRequest::new(1, vec![5, 9, 17], 6));
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert_eq!(r.id, 1);
    assert_eq!(r.tokens.len(), 6);
    assert!(r.tokens.iter().all(|&t| t >= 0 && t < 512));
    assert!(r.ttft_s >= 0.0 && r.total_s >= r.ttft_s);
}

#[test]
fn decoding_is_deterministic_across_groups() {
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    server.submit(DecodeRequest::new(1, vec![7, 3], 5));
    let a = server.drain().unwrap();
    server.submit(DecodeRequest::new(2, vec![7, 3], 5));
    let b = server.drain().unwrap();
    assert_eq!(a[0].tokens, b[0].tokens, "same prompt must yield same tokens");
}

#[test]
fn batched_group_matches_solo_decoding() {
    // Group members must not contaminate each other: decoding a prompt in
    // a padded batch-4 group yields the same tokens as decoding it alone.
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    server.submit(DecodeRequest::new(1, vec![11, 22, 33], 5));
    let solo = server.drain().unwrap();

    for (id, prompt) in [(10u64, vec![11, 22, 33]), (11, vec![100, 200]), (12, vec![42])] {
        server.submit(DecodeRequest::new(id, prompt, 5));
    }
    let grouped = server.drain().unwrap();
    let in_group = grouped.iter().find(|r| r.id == 10).unwrap();
    assert_eq!(in_group.tokens, solo[0].tokens);
}

#[test]
fn mixed_lengths_complete_and_respect_budgets() {
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    server.submit(DecodeRequest::new(1, vec![1], 2));
    server.submit(DecodeRequest::new(2, vec![2, 3, 4, 5], 8));
    server.submit(DecodeRequest::new(3, vec![6, 7], 1));
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 3);
    let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
    assert_eq!(by_id(1).tokens.len(), 2);
    assert_eq!(by_id(2).tokens.len(), 8);
    assert_eq!(by_id(3).tokens.len(), 1);
}

#[test]
fn invalid_requests_fail_without_aborting_the_drain() {
    // DESIGN.md §14: an invalid request ends as a typed Failed outcome —
    // it never takes the serving loop (or its groupmates) down.
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    // token outside the tiny model's 512 vocab
    server.submit(DecodeRequest::new(1, vec![100000], 2));
    server.submit(DecodeRequest::new(2, vec![5, 9], 2));
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 2);
    let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
    let bad = by_id(1);
    assert_eq!(bad.outcome, Outcome::Failed);
    assert!(bad.tokens.is_empty());
    assert!(bad.error.as_deref().unwrap_or("").contains("vocab"));
    let good = by_id(2);
    assert_eq!(good.outcome, Outcome::Completed);
    assert_eq!(good.tokens.len(), 2, "groupmates of an invalid request still decode");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests_failed, 1);
    assert_eq!(snap.requests_completed, 1);
    assert!(snap.outcomes_accounted());
}

#[test]
fn metrics_track_groups_and_padding() {
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    server.submit(DecodeRequest::new(1, vec![5], 3));
    server.submit(DecodeRequest::new(2, vec![6], 3));
    server.submit(DecodeRequest::new(3, vec![7], 3));
    let _ = server.drain().unwrap();
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests_completed, 3);
    assert!(snap.groups_formed >= 1);
    // 3 requests into batch-4 artifact -> at least one padded slot.
    assert!(snap.padded_slots >= 1);
    assert_eq!(snap.tokens_generated, 9);
}

#[test]
fn all_four_gemm_kinds_appear_after_one_routed_batch() {
    // Regression: metrics must cover every routed projection GEMM, not
    // just the down-projection.
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    server.submit(DecodeRequest::new(1, vec![5], 2));
    let _ = server.drain().unwrap();
    let snap = server.metrics.snapshot();
    for kind in ["qkv", "attn_out", "up_gate", "down"] {
        assert!(
            snap.gemm_schedules.contains_key(kind),
            "missing '{kind}' in gemm_schedules: {:?}",
            snap.gemm_schedules
        );
    }
}

#[test]
fn router_caches_engines_per_batch_size() {
    let rt = Runtime::cpu().unwrap();
    let Some(mut server) = setup(&rt) else { return };
    server.submit(DecodeRequest::new(1, vec![1], 1));
    let _ = server.drain().unwrap();
    let first = server.router.engines_built();
    server.submit(DecodeRequest::new(2, vec![2], 1));
    let _ = server.drain().unwrap();
    assert_eq!(server.router.engines_built(), first, "engine must be reused");
}
