//! Integration: load real AOT artifacts through PJRT and validate the
//! numerics against the rust host reference (quantize -> dequantize ->
//! f16-rounded GEMM).  This is the end-to-end proof that the three layers
//! (Pallas kernel, JAX graph, rust runtime) compose.
//!
//! Requires `make artifacts` (skips itself politely otherwise).

use ascend_w4a16::quant;
use ascend_w4a16::runtime::{HostTensor, Manifest, Runtime};
use ascend_w4a16::runtime::client::literal_to_host;
use ascend_w4a16::tensor::MatF32;
use ascend_w4a16::util::prng::Rng;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn manifest() -> Option<Manifest> {
    if !std::path::Path::new(ARTIFACTS).join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(ARTIFACTS).expect("manifest parses"))
}

/// Build a random quantized GEMM case matching an artifact's (m, n, k).
fn gemm_case(m: usize, n: usize, k: usize, seed: u64) -> (MatF32, quant::QuantizedWeight) {
    let mut rng = Rng::new(seed);
    let a = MatF32::from_vec(m, k, rng.normal_vec(m * k, 0.5));
    let w = MatF32::from_vec(k, n, rng.normal_vec(k * n, 0.05));
    let qw = quant::quantize_groupwise(&w, 128, false).unwrap();
    (a, qw)
}

fn run_w4a16_artifact(rt: &Runtime, mf: &Manifest, name: &str) -> (MatF32, MatF32) {
    let entry = mf.find(name).unwrap();
    let (m, n, k) = entry.gemm.unwrap();
    let (a, qw) = gemm_case(m, n, k, 7);
    let exe = rt.load(entry).unwrap();
    let out = exe
        .run(&[
            HostTensor::F32(a.data.clone()),
            HostTensor::I8(qw.packed.clone()),
            HostTensor::F32(qw.scales.clone()),
            HostTensor::F32(qw.zeros.clone()),
        ])
        .unwrap();
    let got = MatF32::from_vec(
        m,
        n,
        literal_to_host(&out[0]).unwrap().as_f32().unwrap(),
    );
    let want = quant::w4a16_reference(&a, &qw);
    (got, want)
}

#[test]
fn splitk_artifact_matches_reference() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (got, want) = run_w4a16_artifact(&rt, &mf, "splitk_m16_n256_k512");
    assert!(
        got.allclose(&want, 2e-2, 2e-2),
        "max diff {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn dp_and_fused_agree_with_splitk() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (sk, want) = run_w4a16_artifact(&rt, &mf, "splitk_m16_n256_k512");
    let (dp, _) = run_w4a16_artifact(&rt, &mf, "dp_m16_n256_k512");
    let (fu, _) = run_w4a16_artifact(&rt, &mf, "fused_m16_n256_k512");
    assert!(dp.allclose(&want, 2e-2, 2e-2));
    assert!(fu.allclose(&want, 2e-2, 2e-2));
    // Strategies are numerically interchangeable (schedule-only change).
    assert!(sk.allclose(&dp, 1e-2, 1e-2));
    assert!(sk.allclose(&fu, 1e-2, 1e-2));
}

#[test]
fn fp16_artifact_matches_host_gemm() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = mf.find("fp16_m16_n256_k512").unwrap();
    let (m, n, k) = entry.gemm.unwrap();
    let mut rng = Rng::new(11);
    let a = MatF32::from_vec(m, k, rng.normal_vec(m * k, 0.5));
    let b = MatF32::from_vec(k, n, rng.normal_vec(k * n, 0.1));
    let exe = rt.load(entry).unwrap();
    let out = exe
        .run(&[HostTensor::F32(a.data.clone()), HostTensor::F32(b.data.clone())])
        .unwrap();
    let got = MatF32::from_vec(m, n, literal_to_host(&out[0]).unwrap().as_f32().unwrap());
    let want = a.matmul_f16acc(&b);
    assert!(got.allclose(&want, 2e-2, 2e-2), "max diff {}", got.max_abs_diff(&want));
}

#[test]
fn larger_shape_splitk() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (got, want) = run_w4a16_artifact(&rt, &mf, "splitk_m16_n512_k2048");
    assert!(got.allclose(&want, 3e-2, 3e-2), "max diff {}", got.max_abs_diff(&want));
}

#[test]
fn executable_cache_dedups() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = mf.find("fp16_m16_n256_k512").unwrap();
    let a = rt.load(entry).unwrap();
    let b = rt.load(entry).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert_eq!(rt.cached(), 1);
}

#[test]
fn wrong_arity_and_dtype_rejected() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = mf.find("fp16_m16_n256_k512").unwrap();
    let exe = rt.load(entry).unwrap();
    assert!(exe.run(&[HostTensor::F32(vec![0.0; 16 * 512])]).is_err());
    assert!(exe
        .run(&[
            HostTensor::I8(vec![0; 16 * 512]),
            HostTensor::F32(vec![0.0; 512 * 256]),
        ])
        .is_err());
}

#[test]
fn tiny_decode_step_executes() {
    let Some(mf) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = mf.decode("tiny", 1).unwrap();
    let cfg = entry.config.unwrap();
    let weights = entry.weights.as_ref().unwrap().load().unwrap();
    let exe = rt.load(entry).unwrap();

    // Input order: token_ids, positions, kv_cache, then params sorted by name.
    let mut args = vec![
        HostTensor::I32(vec![5]),
        HostTensor::I32(vec![0]),
        HostTensor::F32(vec![0.0; cfg.layers * 2 * cfg.max_seq * cfg.hidden]),
    ];
    for spec in &entry.inputs[3..] {
        let raw = weights.get(&spec.name).expect("weight present");
        args.push(HostTensor::from_bytes(spec.dtype, raw).unwrap());
    }
    let out = exe.run(&args).unwrap();
    assert_eq!(out.len(), 3);
    let logits = literal_to_host(&out[0]).unwrap().as_f32().unwrap();
    assert_eq!(logits.len(), cfg.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
    let next = match literal_to_host(&out[1]).unwrap() {
        HostTensor::I32(v) => v,
        other => panic!("next_token dtype {:?}", other.dtype()),
    };
    assert!(next[0] >= 0 && (next[0] as usize) < cfg.vocab);
    // argmax(logits) must equal next_token
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(argmax as i32, next[0]);
}
