//! Preemption property suite (DESIGN.md §18): the serve loop's
//! recompute/swap/auto victim-eviction paths on synthetic (config-only)
//! manifests, from clean runs to 50% fault rates.
//!
//! The invariants:
//! * outcome conservation survives preemption — `admitted == completed +
//!   shed + expired + failed` AND the preemption ledger closes
//!   (`preempted == resumed + lost == recompute + swap`) at every load
//!   level, KV budget, policy and fault rate;
//! * the KV pager conserves pages across arbitrary preempt/resume
//!   cycles: capacity is never exceeded, a preempted victim holds
//!   nothing while parked, and the pager always drains to idle;
//! * recovery is lossless — every completed request's token stream is
//!   bit-identical to the roomy-KV no-preemption baseline, including
//!   requests that were preempted and resumed mid-generation;
//! * preemption is bounded — each victim is evicted at most
//!   `max_preemptions` times, so tiny-KV overload terminates (no
//!   livelock) with `preempted <= admitted * max_preemptions`.

use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::coordinator::{
    BatchPolicy, Batcher, FaultPlan, Outcome, PreemptPolicy, Router, ServeOptions, Server,
};
use ascend_w4a16::model::KvPager;
use ascend_w4a16::runtime::artifacts::DecodeConfig;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::util::proptest::forall;
use ascend_w4a16::workload::{ArrivalPlan, DecodeLayer};

/// The chaos-harness tiny model: three config-only decode artifacts
/// (batch 1/2/4), so the router builds synthetic engines.
fn manifest_json() -> String {
    let artifact = |batch: usize| {
        format!(
            r#"    {{
      "name": "decode_tiny_b{batch}",
      "kind": "decode",
      "path": "decode_tiny_b{batch}.hlo.txt",
      "model": "tiny",
      "batch": {batch},
      "config": {{"vocab": 512, "hidden": 256, "layers": 2, "heads": 4,
                 "ffn": 1024, "max_seq": 64, "group": 128, "params": 0}},
      "inputs": [],
      "outputs": []
    }}"#
        )
    };
    format!(
        "{{\n  \"group\": 128,\n  \"batch_sizes\": [1, 2, 4],\n  \"paper_shapes\": [],\n  \"artifacts\": [\n{},\n{},\n{}\n  ]\n}}",
        artifact(1),
        artifact(2),
        artifact(4)
    )
}

fn decode_config() -> DecodeConfig {
    DecodeConfig {
        vocab: 512,
        hidden: 256,
        layers: 2,
        heads: 4,
        ffn: 1024,
        max_seq: 64,
        group: 128,
        params: 0,
        moe_experts: 0,
        moe_topk: 0,
    }
}

/// Manifest plus a fully warmed tune cache, so every serve run here is
/// cache-only on the `full` rung (same scaffold as tests/serve_load.rs).
fn preempt_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("w4a16-preempt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
    let mut tuner = Tuner::new(MachineConfig::ascend910());
    for batch in [1usize, 2, 4, 32] {
        let layer = DecodeLayer::from_decode_config(&decode_config(), batch);
        for node in layer.gemm_nodes() {
            tuner.resolve(&node.problem).unwrap();
        }
        for pair in layer.overlap_pairs() {
            tuner.resolve_overlap(&pair.producer, &pair.consumer).unwrap();
        }
        tuner.resolve_residency(&layer).unwrap();
    }
    tuner.save_to(dir.join("tune_cache.json")).unwrap();
    dir
}

fn build_server<'rt>(rt: &'rt Runtime, dir: &std::path::Path) -> Server<'rt> {
    let mf = Manifest::load(dir).unwrap();
    let router = Router::new(rt, mf, "tiny").unwrap();
    let policy = BatchPolicy::new(router.batch_sizes()).unwrap();
    Server::new(router, Batcher::new(policy))
}

const POLICIES: [PreemptPolicy; 3] =
    [PreemptPolicy::Recompute, PreemptPolicy::Swap, PreemptPolicy::Auto];

#[test]
fn conservation_survives_preemption_under_chaos() {
    // The §14/§15 conservation law with the preemption path armed and a
    // fault plan firing at rates up to 50%: admission faults, step
    // faults, cache-write faults, preempt-recovery and swap-in faults
    // all interleave with victim eviction, and every request must still
    // land in exactly one terminal outcome while the preemption ledger
    // closes and the pager drains.
    let dir = preempt_dir("chaos");
    let rt = Runtime::cpu().unwrap();
    forall("preempt conservation under faults", 12, |rng| {
        let n = rng.usize_range(4, 32);
        let mean_gap_us = 10f64.powf(rng.f64() * 2.5); // 1 µs .. ~300 µs
        let plan = ArrivalPlan::poisson(rng.next_u64(), mean_gap_us, n, 64);
        let policy = POLICIES[rng.usize_range(0, 2)];
        // One worst-case tiny-model request reserves up to 32 pages of
        // 4 KiB, so 24..72 pages spans "nothing fits" to "two fit".
        let pages = rng.usize_range(24, 72) as u64;
        let opts = ServeOptions::new([2usize, 4][rng.usize_range(0, 1)], rng.usize_range(1, 6))
            .with_queue_cap(rng.usize_range(2, 16))
            .with_page_bytes(4096)
            .with_kv_capacity_bytes(pages * 4096)
            .with_preempt(policy)
            .with_max_preemptions(rng.usize_range(1, 4) as u32);
        let mut server = build_server(&rt, &dir);
        server.set_faults(Some(FaultPlan::new(rng.next_u64(), rng.f64() * 0.5)));
        let report = match server.serve_load(&plan, &opts) {
            Ok(r) => r,
            Err(e) => return (false, format!("serve_load errored: {e:#}")),
        };
        if !report.kv_idle {
            return (false, "kv pager leaked pages".into());
        }
        if report.kv_peak_pages > report.kv_capacity_pages {
            return (
                false,
                format!("peak {} > capacity {}", report.kv_peak_pages, report.kv_capacity_pages),
            );
        }
        let snap = server.metrics.snapshot();
        if snap.requests_admitted != n as u64 {
            return (false, format!("admitted {} != offered {n}", snap.requests_admitted));
        }
        if !snap.outcomes_accounted() {
            return (
                false,
                format!(
                    "admitted {} != {} + {} + {} + {}",
                    snap.requests_admitted,
                    snap.requests_completed,
                    snap.requests_shed,
                    snap.requests_expired,
                    snap.requests_failed
                ),
            );
        }
        if !snap.sheds_accounted() {
            return (false, format!("typed sheds must close: {:?}", snap.shed_reasons));
        }
        if !snap.preemptions_accounted() {
            return (
                false,
                format!(
                    "preemption ledger must close: {} preempted != {} resumed + {} lost \
                     (or != {} recompute + {} swap)",
                    snap.requests_preempted,
                    snap.requests_resumed,
                    snap.requests_preempt_failed,
                    snap.preempt_recompute,
                    snap.preempt_swap
                ),
            );
        }
        (true, String::new())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pager_conserves_pages_across_preempt_resume_cycles() {
    // Direct KvPager property: random admit / grow / preempt / resume /
    // release schedules against a shadow model.  A preempted sequence
    // holds NOTHING (pages or reservation) while parked, a sequence that
    // fit once always fits again on an otherwise-empty pager, and the
    // pager ends idle once everything is released.
    forall("pager preempt/resume conservation", 48, |rng| {
        let page_bytes = [256u64, 1024, 4096][rng.usize_range(0, 2)];
        let capacity_pages = rng.usize_range(8, 128) as u64;
        let mut pager = KvPager::new(page_bytes, capacity_pages * page_bytes);
        // id -> (tokens_now, budget_total, bytes_per_token) for resident
        // sequences; parked carries the same tuple for preempted ones.
        let mut resident: Vec<(u64, usize, usize, u64)> = Vec::new();
        let mut parked: Vec<(u64, usize, usize, u64)> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..rng.usize_range(20, 120) {
            match rng.usize_range(0, 4) {
                0 => {
                    // Admit a fresh sequence.
                    let prompt = rng.usize_range(1, 16);
                    let max_new = rng.usize_range(1, 32);
                    let bpt = [64u64, 2048][rng.usize_range(0, 1)];
                    if pager.try_admit(next_id, prompt, max_new, bpt) {
                        resident.push((next_id, prompt, prompt + max_new, bpt));
                    }
                    next_id += 1;
                }
                1 => {
                    // Grow a resident sequence within its reservation.
                    if !resident.is_empty() {
                        let i = rng.usize_range(0, resident.len() - 1);
                        if resident[i].1 < resident[i].2 {
                            pager.grow(resident[i].0);
                            resident[i].1 += 1;
                        }
                    }
                }
                2 => {
                    // Preempt: the victim must drop its pages AND its
                    // reservation — the returned footprint prices the
                    // recovery path.
                    if !resident.is_empty() {
                        let i = rng.usize_range(0, resident.len() - 1);
                        let held = pager.pages_of(resident[i].0).unwrap();
                        let before = (pager.allocated_pages(), pager.reserved_pages());
                        let (pages, bytes) = pager.preempt(resident[i].0);
                        if pages != held || bytes != pages * page_bytes {
                            return (
                                false,
                                format!("preempt returned {pages}p/{bytes}B, held {held}p"),
                            );
                        }
                        if pager.allocated_pages() != before.0 - pages {
                            return (false, "preempt must free the victim's pages".into());
                        }
                        if pager.reserved_pages() >= before.1 {
                            return (false, "preempt must drop the reservation".into());
                        }
                        parked.push(resident.swap_remove(i));
                    }
                }
                _ => {
                    // Resume a parked victim at its resume footprint.
                    if !parked.is_empty() {
                        let i = rng.usize_range(0, parked.len() - 1);
                        let (id, tokens, budget, bpt) = parked[i];
                        if pager.try_resume(id, tokens, budget - tokens, bpt) {
                            parked.swap_remove(i);
                            resident.push((id, tokens, budget, bpt));
                        }
                    }
                }
            }
            if pager.reserved_pages() > pager.capacity_pages() {
                return (false, "reservation escaped capacity".into());
            }
            if pager.allocated_pages() > pager.reserved_pages() {
                return (false, "allocation escaped the reservation".into());
            }
            if pager.in_flight() != resident.len() {
                return (
                    false,
                    format!("{} in flight != {} resident", pager.in_flight(), resident.len()),
                );
            }
        }
        // Fit-once-fits-again: drain the residents, then every parked
        // victim must re-seat on the now-empty pager.
        for (id, _, _, _) in resident.drain(..) {
            pager.release(id);
        }
        for (id, tokens, budget, bpt) in parked.drain(..) {
            if !pager.try_resume(id, tokens, budget - tokens, bpt) {
                return (false, format!("victim {id} did not fit an empty pager"));
            }
            pager.release(id);
        }
        if !pager.idle() {
            return (
                false,
                format!(
                    "pager must drain to idle: {} allocated, {} reserved, {} in flight",
                    pager.allocated_pages(),
                    pager.reserved_pages(),
                    pager.in_flight()
                ),
            );
        }
        (true, String::new())
    });
}

#[test]
fn resumed_requests_complete_with_bit_identical_tokens() {
    // Lossless recovery: a roomy-KV, preemption-off baseline completes
    // all ten requests; a 32-page budget then forces victim eviction
    // under every policy (the 80 µs mean gap lands arrivals mid-decode,
    // so LRU victims exist).  Every request the tight run completes —
    // which includes every preempted-and-resumed one, since nothing
    // else is terminal here — must reproduce the baseline stream
    // exactly: recompute re-prefills position-exact, swap restores the
    // identical pages.
    let dir = preempt_dir("tokens");
    let rt = Runtime::cpu().unwrap();
    let plan = ArrivalPlan::poisson(9, 80.0, 10, 64);

    let roomy = ServeOptions::new(4, 4).with_queue_cap(1024);
    let mut server = build_server(&rt, &dir);
    let base = server.serve_load(&plan, &roomy).unwrap();
    let base_snap = server.metrics.snapshot();
    assert_eq!(base_snap.requests_completed, 10, "roomy baseline must complete everything");
    assert_eq!(base_snap.requests_preempted, 0, "roomy baseline must never preempt");
    let baseline: std::collections::BTreeMap<u64, Vec<i32>> =
        base.results.into_iter().map(|r| (r.id, r.tokens)).collect();

    for policy in POLICIES {
        let opts = ServeOptions::new(4, 4)
            .with_queue_cap(1024)
            .with_page_bytes(4096)
            .with_kv_capacity_bytes(32 * 4096)
            .with_preempt(policy);
        let mut server = build_server(&rt, &dir);
        let report = server.serve_load(&plan, &opts).unwrap();
        assert!(report.kv_idle, "{policy:?}: pager must drain");
        let snap = server.metrics.snapshot();
        assert!(snap.outcomes_accounted());
        assert!(snap.sheds_accounted());
        assert!(snap.preemptions_accounted());
        assert!(
            snap.requests_preempted > 0,
            "{policy:?}: a 32-page budget must preempt under this plan"
        );
        assert_eq!(
            snap.requests_resumed, snap.requests_preempted,
            "{policy:?}: without faults every victim resumes"
        );
        match policy {
            PreemptPolicy::Recompute => {
                assert!(snap.recompute_ticks > 0, "recompute must re-prefill");
                assert_eq!(snap.swap_bytes, 0, "recompute must not touch the host link");
            }
            PreemptPolicy::Swap => {
                assert!(snap.swap_bytes > 0, "swap must move pages over the host link");
                assert_eq!(snap.recompute_ticks, 0, "swap must not re-prefill");
            }
            _ => {}
        }
        let mut completed = 0usize;
        for r in &report.results {
            if r.outcome != Outcome::Completed {
                continue;
            }
            completed += 1;
            assert_eq!(
                Some(&r.tokens),
                baseline.get(&r.id),
                "{policy:?}: request {} must reproduce the baseline stream",
                r.id
            );
        }
        assert!(completed > 0, "{policy:?}: the tight run must still complete requests");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bounded_preemption_never_livelocks_under_tiny_kv() {
    // The no-livelock guarantee: each victim is evicted at most
    // `max_preemptions` times, so even a budget that fits one request
    // (or none) under sustained pressure terminates — `serve_load`
    // returning at all is the termination proof — and the global
    // preemption count is bounded by `admitted * max_preemptions`.
    let dir = preempt_dir("livelock");
    let rt = Runtime::cpu().unwrap();
    forall("bounded preemption no livelock", 16, |rng| {
        let n = rng.usize_range(6, 24);
        let mean_gap_us = 10f64.powf(rng.f64() * 2.0); // 1 µs .. 100 µs
        let plan = ArrivalPlan::poisson(rng.next_u64(), mean_gap_us, n, 64);
        let policy = POLICIES[rng.usize_range(0, 2)];
        let max_preemptions = rng.usize_range(1, 3) as u32;
        let pages = rng.usize_range(24, 40) as u64;
        let opts = ServeOptions::new(4, 4)
            .with_queue_cap(rng.usize_range(4, 16))
            .with_page_bytes(4096)
            .with_kv_capacity_bytes(pages * 4096)
            .with_preempt(policy)
            .with_max_preemptions(max_preemptions);
        let mut server = build_server(&rt, &dir);
        let report = match server.serve_load(&plan, &opts) {
            Ok(r) => r,
            Err(e) => return (false, format!("serve_load errored: {e:#}")),
        };
        if !report.kv_idle {
            return (false, "kv pager leaked pages".into());
        }
        let snap = server.metrics.snapshot();
        let bound = snap.requests_admitted * max_preemptions as u64;
        if snap.requests_preempted > bound {
            return (
                false,
                format!(
                    "preempted {} > admitted {} x max_preemptions {max_preemptions}",
                    snap.requests_preempted, snap.requests_admitted
                ),
            );
        }
        if !snap.outcomes_accounted() || !snap.sheds_accounted() || !snap.preemptions_accounted()
        {
            return (false, format!("conservation must close: {snap:?}"));
        }
        (true, String::new())
    });
    let _ = std::fs::remove_dir_all(&dir);
}
