//! End-to-end decode bench: real PJRT execution of the AOT decode-step
//! artifacts (the W4A16 pipeline inside a ~100M-parameter transformer).
//!
//! Absolute numbers are CPU-PJRT wallclock (the substrate is a CPU
//! emulation of the NPU), so only the *relative* batch-scaling shape is
//! meaningful: step latency should grow sublinearly with batch size, i.e.
//! tokens/s should improve with batching — the premise of the serving
//! coordinator.  Requires `make artifacts`.
//! Run with `cargo bench --bench e2e_decode`.

use ascend_w4a16::bench::{section, Bench};
use ascend_w4a16::model::DecodeEngine;
use ascend_w4a16::runtime::{Manifest, Runtime};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping e2e bench: run `make artifacts` first");
        return;
    }
    let mf = Manifest::load(dir).expect("manifest");
    let rt = Runtime::cpu().expect("pjrt");

    for model in ["tiny", "small100m"] {
        section(&format!("decode step latency — model '{model}' (CPU PJRT)"));
        // small100m steps cost seconds of CPU wallclock; probe the batch
        // scaling shape with the extreme sizes only.
        let batches: Vec<usize> = if model == "tiny" {
            mf.decode_batches(model)
        } else {
            let all = mf.decode_batches(model);
            vec![*all.first().unwrap(), *all.last().unwrap()]
        };
        for batch in batches {
            let entry = mf.decode(model, batch).unwrap();
            let mut engine = DecodeEngine::new(&rt, entry).expect("engine");
            let tokens = vec![1i32; batch];
            let mut step_no = 0usize;
            let max_seq = engine.max_seq;
            let iters = if model == "tiny" { 20 } else { 3 };
            let r = Bench::new(format!("{model} b={batch} decode step"))
                .warmup(2)
                .iters(iters)
                .run(|| {
                    let positions = vec![(step_no % (max_seq - 1)) as i32; batch];
                    if step_no % (max_seq - 1) == 0 {
                        engine.reset().unwrap();
                    }
                    engine.step(&tokens, &positions).unwrap();
                    step_no += 1;
                });
            let per_tok = r.summary_ns.mean / batch as f64;
            println!(
                "{}   -> {:.1} tokens/s aggregate",
                r.render_row(),
                1e9 / per_tok
            );
        }
    }
    println!("\nexpected shape: tokens/s grows with batch (weights are read once per step regardless of batch — the W4A16 premise).");
}
