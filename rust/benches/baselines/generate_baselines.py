#!/usr/bin/env python3
"""Generate the committed bench baselines from the offline timing mirror.

The CI bench-diff gate (rust/src/bench/diff.rs) compares each
`BENCH_*.json` produced by `cargo bench` against the files in this
directory and fails on any gated latency cell more than 2% slower.  The
authoring environment has no Rust toolchain, so these baselines come
from `mirror_sim.py` — a double-precision mirror of the simulator whose
values agree with the Rust run to ~1e-12 relative (the simulator is
pure, deterministic f64 arithmetic; see mirror_sim.py's header).

The baselines are deliberately a *subset* of the bench output: the
top-level gated latency cells per (model, batch) identity, without the
`detail`/`step_detail` subtrees.  bench-diff only checks keys present
in the baseline, so the benches stay free to grow columns; re-bless
with `repro bench-diff --bless` from a green `cargo bench` run whenever
a PR intentionally moves the numbers (see README.md).
"""

import json
import math
import os

import mirror_sim as M

HERE = os.path.dirname(os.path.abspath(__file__))

PAPER_SHAPES = [
    ("llama32", 2048, 2048), ("llama32", 8192, 2048), ("llama32", 2048, 8192),
    ("glm45", 5120, 5120), ("glm45", 12288, 5120), ("glm45", 5120, 12288),
    ("deepseek", 7168, 7168), ("deepseek", 2048, 7168), ("deepseek", 7168, 2048),
    ("deepseek", 1536, 7168),
    ("openpangu", 7680, 7680), ("openpangu", 1536, 7680),
]
PAPER_BATCHES = [1, 2, 4, 8, 16, 32, 64]

LAYER_MODELS = [
    ("llama32", 2048, 8192, 2048, None),
    ("glm45", 5120, 12288, 5120, None),
    ("deepseek", 7168, 2048, 1536, None),
    ("openpangu", 7680, 7680, 1536, None),
    ("deepseek-moe", 7168, 2048, 1536, (256, 8, 2048)),
]


def geomean(xs):
    if not xs:
        return 0.0
    return math.exp(sum(math.log(max(x, 1e-300)) for x in xs) / float(len(xs)))


def bench_chunked():
    cells = []
    for model, n, k in PAPER_SHAPES:
        for batch in PAPER_BATCHES:
            p = (batch, n, k, 128)
            t = M.select_chunked(p)
            ck = M.run(M.chunked_schedule(p, t), want_ledger=True)
            sk = M.run(M.schedule(p, "splitk"), want_ledger=True)
            fp16 = M.run(M.schedule(p, "fp16_native"))
            ws_sk = sk.ledger.get(M.WS, [0.0] * 4)
            ws_ck = ck.ledger.get(M.WS, [0.0] * 4)
            cells.append({
                "model": model, "n": n, "k": k, "batch": batch,
                "chunks": t["chunks"],
                "chunked_us": ck.total_ns / 1e3,
                "splitk_us": sk.total_ns / 1e3,
                "fp16_us": fp16.total_ns / 1e3,
                "speedup_vs_splitk": sk.total_ns / ck.total_ns,
                "speedup_vs_fp16": fp16.total_ns / ck.total_ns,
                "ws_hbm_splitk_bytes": ws_sk[0] + ws_sk[1],
                "ws_hbm_chunked_bytes": ws_ck[0] + ws_ck[1],
            })
    kd = [c["splitk_us"] / c["chunked_us"] for c in cells if c["k"] >= 2 * c["n"]]
    strategy, _, tuned_ns = M.tune_search((8, 512, 16384, 128))
    return {
        "bench": "ablation_chunked",
        "cells": cells,
        "geomean_speedup_vs_splitk_k_dominant": geomean(kd),
        "ws_hbm_bytes_splitk_total": sum(c["ws_hbm_splitk_bytes"] for c in cells),
        "ws_hbm_bytes_chunked_total": sum(c["ws_hbm_chunked_bytes"] for c in cells),
        "tuned_decode_strategy": strategy,
        "tuned_decode_ns": tuned_ns,
    }


def bench_layer():
    tuner = M.Tuner()

    def tuned(problem):
        s, t, _ = tuner.resolve(problem)
        return s, t

    def forced_split(problem):
        t = M.select_tiling(problem, "splitk")
        t2 = dict(t, splits=max(t["splits"], 2))
        if M.tiling_validate(t2, problem):
            t = t2
        return "splitk", t

    def cell(model, moe, batch, rep):
        gemms = [n for n in rep["nodes"] if isinstance(n, dict)]
        layer_ns = 0.0
        barrier_ns = 0.0
        for g in gemms:
            layer_ns += g["total_ns"]
        for g in gemms:
            barrier_ns += g["barrier_ns"]
        auto_base = min(rep["exact_ns"], rep["overlapped_ns"], rep["sequential_ns"])
        plan = rep["residency"]
        return {
            "model": model, "moe": moe, "batch": batch,
            "layer_us": layer_ns / 1e3,
            "layer_barrier_us": barrier_ns / 1e3,
            "reduce_pipeline_speedup": barrier_ns / layer_ns,
            "step_us": rep["served_ns"] / 1e3,
            "step_sequential_us": rep["sequential_ns"] / 1e3,
            "step_exact_us": rep["exact_ns"] / 1e3,
            "step_resident_us": plan["resident_ns"] / 1e3,
            "residency_speedup": auto_base / rep["served_ns"],
            "residency_gain_us": plan["gain_ns"] / 1e3,
            "residency_pinned_bytes": float(plan["pinned_bytes"]),
            "overlap_speedup": rep["sequential_ns"] / rep["served_ns"],
            "overlap_exact_speedup": rep["sequential_ns"] / rep["exact_ns"],
            "overlap_exact_vs_ledger": rep["overlapped_ns"] / rep["exact_ns"],
        }

    cells = []
    for model, hidden, ffn, kv, moe in LAYER_MODELS:
        heads = max(hidden // 128, 1)
        for batch in (1, 8, 64):
            rep = M.simulate_step_with(batch, 2048, heads, hidden, ffn, kv, 128,
                                       moe, tuned, "auto", "auto")
            cells.append(cell(model, moe is not None, batch, rep))
    for model, hidden, ffn, kv, moe in LAYER_MODELS:
        if model not in ("llama32", "deepseek-moe"):
            continue
        heads = max(hidden // 128, 1)
        rep = M.simulate_step_with(8, 2048, heads, hidden, ffn, kv, 128, moe,
                                   forced_split, "auto", "auto")
        auto_base = min(rep["exact_ns"], rep["overlapped_ns"], rep["sequential_ns"])
        plan = rep["residency"]
        cells.append({
            "model": f"{model}-forced-split", "moe": moe is not None, "batch": 8,
            "step_us": rep["served_ns"] / 1e3,
            "step_sequential_us": rep["sequential_ns"] / 1e3,
            "step_exact_us": rep["exact_ns"] / 1e3,
            "step_resident_us": plan["resident_ns"] / 1e3,
            "residency_speedup": auto_base / rep["served_ns"],
            "residency_gain_us": plan["gain_ns"] / 1e3,
            "overlap_speedup": rep["sequential_ns"] / rep["overlapped_ns"],
            "overlap_exact_speedup": rep["sequential_ns"] / rep["exact_ns"],
            "overlap_exact_vs_ledger": rep["overlapped_ns"] / rep["exact_ns"],
        })
    # Precision-family sweep (benches/e2e_layer.rs bench_precision_sweep):
    # the tuned W4A16 winner vs the tuned W4A8-tagged winner per paper
    # shape at batch 8, plus the paper's headline decode shape.  The
    # `w4a16_us`/`w4a8_us` cells gate; `w4a8_speedup` is a ratio.
    for model, n, k in PAPER_SHAPES + [("decode", 512, 16384)]:
        p = (8, n, k, 128)
        s16, _, ns16 = M.tune_search(p)
        s8, _, ns8 = M.tune_search_w4a8(p)
        cells.append({
            "model": f"{model}:{n}x{k}", "n": n, "k": k, "batch": 8,
            "w4a16_us": ns16 / 1e3,
            "w4a16_strategy": s16,
            "w4a8_us": ns8 / 1e3,
            "w4a8_strategy": s8,
            "w4a8_speedup": ns16 / ns8,
        })
    return {"bench": "e2e_layer", "kv_len": 2048, "cells": cells}


SERVE_MODELS = [
    ("llama32", {"hidden": 2048, "layers": 16, "heads": 16, "ffn": 8192,
                 "max_seq": 256, "group": 128, "moe": None}),
    ("deepseek-moe", {"hidden": 7168, "layers": 4, "heads": 56, "ffn": 2048,
                      "max_seq": 256, "group": 128, "moe": (256, 8, 2048)}),
]
SERVE_BATCH = 8
SERVE_CHUNK = 32
SERVE_QUEUE_CAP = 12
SERVE_REQUESTS = 48
SERVE_SEED = 11
SERVE_GAPS = [20_000.0, 2_000.0, 200.0, 20.0]
# Armed preemption overload leg (mirrors benches/e2e_serve.rs): per-model
# KV capacity + anti-starvation window chosen so that at the deep-overload
# gap `auto` strictly beats `off` on both goodput and p99 TTFT, while at
# the light gap the two policies are bit-identical (preemption never arms).
PREEMPT_GAP = 50.0
PREEMPT_LEG = {
    "llama32": {"capacity_bytes": 300 << 20, "max_wait_us": 6_000,
                "light_gap_us": 20_000.0},
    "deepseek-moe": {"capacity_bytes": 192 << 20, "max_wait_us": 50_000,
                     "light_gap_us": 100_000.0},
}


def bench_serve():
    """Replay of benches/e2e_serve.rs: warm the tune caches in the bench's
    exact seeding order (m = 1..=chunk then the decode batch — padded-M
    aliasing means the first m of each class prices the entry), then run
    the serve event loop per (model, mean-gap) cell."""
    cells = []
    for model, cfg in SERVE_MODELS:
        planner = M.ServePlanner()
        for m in list(range(1, SERVE_CHUNK + 1)) + [SERVE_BATCH]:
            planner.warm(M.decode_gemm_nodes(m, cfg["hidden"], cfg["ffn"],
                                             cfg["group"], cfg["moe"]))
        for gap in SERVE_GAPS:
            arrivals = M.poisson_plan(SERVE_SEED, gap, SERVE_REQUESTS,
                                      cfg["max_seq"])
            offered = sum(a[2] for a in arrivals)
            plan_horizon = arrivals[-1][0] if arrivals else 0
            rep = M.serve_load(cfg, planner, arrivals, SERVE_BATCH,
                               SERVE_CHUNK, SERVE_QUEUE_CAP)
            assert rep["admitted"] == rep["completed"] + rep["shed"]
            ttft = sorted(rep["ttft_us"])
            gaps = sorted(rep["gap_us"])
            horizon = rep["horizon_us"]
            goodput = (rep["tokens_generated"] / (horizon / 1e6)
                       if horizon > 0 else 0.0)
            cells.append({
                "model": model,
                "moe": cfg["moe"] is not None,
                "mean_gap_us": gap,
                "offered_tokens": offered,
                "offered_tok_per_s": offered / (max(plan_horizon, 1) / 1e6),
                "goodput_tok_per_s": goodput,
                "horizon_us": horizon,
                "admitted": rep["admitted"],
                "completed": rep["completed"],
                "shed": rep["shed"],
                "shed_queue_full": rep["shed_queue_full"],
                "shed_kv_capacity": rep["shed_kv_capacity"],
                "expired": 0,
                "failed": 0,
                "tokens_generated": rep["tokens_generated"],
                "ttft_p50_us": M.percentile(ttft, 0.50),
                "ttft_p99_us": M.percentile(ttft, 0.99),
                "tok_gap_p50_us": M.percentile(gaps, 0.50),
                "tok_gap_p99_us": M.percentile(gaps, 0.99),
                "prefill_steps": rep["prefill_steps"],
                "prefill_tokens": rep["prefill_tokens"],
                "decode_steps": rep["decode_steps"],
                "repins": rep["repins"],
                "repin_us_sum": rep["repin_ns_sum"] / 1e3,
                "kv_peak_pages": rep["kv_peak_pages"],
                "kv_capacity_pages": rep["kv_capacity_pages"],
            })
        # Armed preemption overload leg.  Light load first: with the same
        # capped pager and batching window, off and auto must be
        # bit-identical (nothing ever arms the preemption path).
        leg = PREEMPT_LEG[model]

        def leg_run(gap, policy):
            arrivals = M.poisson_plan(SERVE_SEED, gap, SERVE_REQUESTS,
                                      cfg["max_seq"])
            return M.serve_load(cfg, planner, arrivals, SERVE_BATCH,
                                SERVE_CHUNK, SERVE_QUEUE_CAP, preempt=policy,
                                capacity_bytes=leg["capacity_bytes"],
                                max_wait_us=leg["max_wait_us"])

        light_off = leg_run(leg["light_gap_us"], "off")
        light_auto = leg_run(leg["light_gap_us"], "auto")
        assert light_off == light_auto, \
            f"{model}: light-load serve must be preemption-invariant"
        assert light_auto["preempted"] == 0
        # Deep overload: auto must strictly beat off on goodput AND p99
        # TTFT — the acceptance gate for the whole subsystem.
        overload = {}
        for policy in ("off", "auto"):
            rep = leg_run(PREEMPT_GAP, policy)
            assert rep["admitted"] == rep["completed"] + rep["shed"]
            assert rep["preempted"] == rep["resumed"]
            ttft = sorted(rep["ttft_us"])
            gaps = sorted(rep["gap_us"])
            horizon = rep["horizon_us"]
            goodput = (rep["tokens_generated"] / (horizon / 1e6)
                       if horizon > 0 else 0.0)
            p99 = M.percentile(ttft, 0.99)
            overload[policy] = (goodput, p99)
            cells.append({
                "model": f"{model}+preempt-{policy}",
                "moe": cfg["moe"] is not None,
                "mean_gap_us": PREEMPT_GAP,
                "preempt": policy,
                "max_wait_us": leg["max_wait_us"],
                "goodput_tok_per_s": goodput,
                "horizon_us": horizon,
                "admitted": rep["admitted"],
                "completed": rep["completed"],
                "shed": rep["shed"],
                "shed_queue_full": rep["shed_queue_full"],
                "shed_kv_capacity": rep["shed_kv_capacity"],
                "tokens_generated": rep["tokens_generated"],
                "ttft_p50_us": M.percentile(ttft, 0.50),
                "ttft_p99_us": p99,
                "tok_gap_p50_us": M.percentile(gaps, 0.50),
                "tok_gap_p99_us": M.percentile(gaps, 0.99),
                "prefill_steps": rep["prefill_steps"],
                "decode_steps": rep["decode_steps"],
                "preempted": rep["preempted"],
                "resumed": rep["resumed"],
                "swap_bytes": rep["swap_bytes"],
                "preempt_swap_us": rep["swap_us_sum"],
                "recompute_ticks": rep["recompute_ticks"],
                "preempt_recompute_us": rep["recompute_us_sum"],
                "kv_peak_pages": rep["kv_peak_pages"],
                "kv_capacity_pages": rep["kv_capacity_pages"],
            })
        assert overload["auto"][0] > overload["off"][0], \
            f"{model}: auto goodput must strictly beat off at deep overload"
        assert overload["auto"][1] < overload["off"][1], \
            f"{model}: auto p99 TTFT must strictly beat off at deep overload"
    return {"bench": "e2e_serve", "batch": SERVE_BATCH, "chunk": SERVE_CHUNK,
            "queue_cap": SERVE_QUEUE_CAP, "requests": SERVE_REQUESTS,
            "seed": SERVE_SEED, "cells": cells}


def main():
    for name, doc in [("BENCH_chunked.json", bench_chunked()),
                      ("BENCH_layer.json", bench_layer()),
                      ("BENCH_serve.json", bench_serve())]:
        path = os.path.join(HERE, name)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
