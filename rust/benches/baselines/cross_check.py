#!/usr/bin/env python3
"""Cross-check the offline timing mirror against a real `cargo bench` run.

`repro bench-diff` is the *regression* gate: it is deliberately
one-sided (only slower-than-baseline fails) and only covers the gated
`*_ns`/`*_us` latency cells.  This script is the *drift* gate for the
mirror itself: every cell the mirror emits — latencies, speedups,
byte counts, tuned chunk counts — must agree with the Rust run to a
symmetric relative tolerance (default 1e-9; the simulator is pure f64
arithmetic mirrored expression-for-expression, so real agreement is
~1e-12).  A mismatch in either direction means the mirror and the
simulator have diverged and one of them is wrong about the timing
model; fix the divergence or re-bless the baselines from the green
cargo run (the ci job uploads it) and update the mirror in the same
PR.

Usage: cross_check.py MIRROR.json CARGO.json [--tol 1e-9]

The cargo output is a superset (detail/step_detail subtrees); only
keys present in the mirror document are checked.  Cells are matched by
identity (model/n/k/batch/moe), not list order, so the benches stay
free to reorder sweeps.
"""

import json
import sys

IDENT_KEYS = ("model", "n", "k", "batch", "moe", "kv_len")
TOL = 1e-9


def rel_close(a, b, tol):
    scale = max(abs(a), abs(b))
    return scale == 0.0 or abs(a - b) <= tol * scale


def ident(cell):
    return tuple((k, cell[k]) for k in IDENT_KEYS if k in cell)


def check_value(path, mirror_v, cargo_v, errors, tol):
    if isinstance(mirror_v, bool) or isinstance(mirror_v, str):
        if mirror_v != cargo_v:
            errors.append(f"{path}: mirror={mirror_v!r} cargo={cargo_v!r}")
    elif isinstance(mirror_v, (int, float)):
        if not isinstance(cargo_v, (int, float)) or isinstance(cargo_v, bool):
            errors.append(f"{path}: cargo value {cargo_v!r} is not numeric")
        elif not rel_close(float(mirror_v), float(cargo_v), tol):
            rel = abs(mirror_v - cargo_v) / max(abs(mirror_v), abs(cargo_v))
            errors.append(
                f"{path}: mirror={mirror_v!r} cargo={cargo_v!r} (rel {rel:.3e})"
            )
    else:
        errors.append(f"{path}: unsupported mirror value {mirror_v!r}")


def check_cell(path, mirror_cell, cargo_cell, errors, tol):
    for key, mirror_v in sorted(mirror_cell.items()):
        if key not in cargo_cell:
            errors.append(f"{path}.{key}: missing from cargo output")
            continue
        check_value(f"{path}.{key}", mirror_v, cargo_cell[key], errors, tol)


def check_doc(mirror, cargo, errors, tol):
    for key, mirror_v in sorted(mirror.items()):
        if key not in cargo:
            errors.append(f"{key}: missing from cargo output")
            continue
        if key == "cells":
            by_ident = {}
            for cell in cargo[key]:
                by_ident.setdefault(ident(cell), cell)
            for i, cell in enumerate(mirror_v):
                label = ", ".join(f"{k}={v}" for k, v in ident(cell))
                match = by_ident.get(ident(cell))
                if match is None:
                    errors.append(f"cells[{label}]: no cargo cell matches")
                else:
                    check_cell(f"cells[{label}]", cell, match, errors, tol)
        else:
            check_value(key, mirror_v, cargo[key], errors, tol)


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--tol")]
    tol = TOL
    for a in argv[1:]:
        if a.startswith("--tol="):
            tol = float(a.split("=", 1)[1])
    if len(args) != 2:
        print(__doc__)
        return 2
    with open(args[0]) as f:
        mirror = json.load(f)
    with open(args[1]) as f:
        cargo = json.load(f)
    errors = []
    check_doc(mirror, cargo, errors, tol)
    if errors:
        print(f"MIRROR DRIFT: {len(errors)} cell(s) disagree (tol {tol:g}):")
        for e in errors[:50]:
            print(f"  {e}")
        if len(errors) > 50:
            print(f"  ... ({len(errors) - 50} more)")
        return 1
    n = sum(len(c) for c in mirror.get("cells", [])) + len(mirror)
    print(f"mirror == cargo bench: {args[0]} vs {args[1]} ({n} values, tol {tol:g})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
