#!/usr/bin/env python3
"""Offline timing mirror of the Rust simulator (rust/src/ascend/*,
kernels/*, tune/*, analysis/{layer,coschedule,residency}.rs).

Purpose: the bench baselines under this directory must carry real
numbers to arm the CI perf gate, and the authoring environment has no
Rust toolchain (see README.md).  The simulator is pure, deterministic
f64 arithmetic, so a faithful Python mirror — IEEE-754 doubles, the
same expressions in the same order — reproduces the bench cells to
double precision; the 2% gate threshold then has ~12 orders of
magnitude of headroom.  `generate_baselines.py` drives this module;
re-bless with a real `cargo bench` run whenever one is available (the
bench-snapshot job uploads the `blessed-baselines` artifact for
exactly that).

Scope: everything the gated top-level BENCH cells need — the machine
model, the five kernel schedules with their tilers, the tuner search,
the reduce/overlap/chain co-scheduler, the vecpass step graph and the
step-level weight-residency planner.  Structural digests (the golden
fixtures) live in rust/tests/fixtures/generate.py.
"""

import math

# --- config.rs -------------------------------------------------------------

AI_CORES = 32
VEC_PER_CORE = 2
VEC_CORES = AI_CORES * VEC_PER_CORE
CLOCK_GHZ = 1.0
CUBE_TILE = 16
CUBE_MACS = 4096.0
CUBE_MACS_INT8 = 8192.0
LANES_F16 = 128.0
LANES_F32 = 64.0
L0A = 64 << 10
L0B = 64 << 10
L0C = 256 << 10
UB = 256 << 10
L2_BYTES = 32 << 20
L2_BW = 3600.0
HBM_BW = 1200.0
MTE_BW = 500.0
L2_RETENTION = 0.90
DMA_BURST = 256.0
LAUNCH_NS = 5000.0
BARRIER_NS = 2000.0
EVENT_NS = 50.0

# Buffer classes (order = the Rust enum's Ord, for ledger iteration).
WP, WF16, ACT, WS, PART, OUT, QP, CPART, CWEIGHT = range(9)


def m_padded(m):
    return -(-m // CUBE_TILE) * CUBE_TILE


def packed_weight_bytes(n, k):
    return k * n // 2


def f16_weight_bytes(n, k):
    return k * n * 2


def macs(m, n, k):
    return m_padded(m) * n * k


# --- cube.rs / vector.rs ---------------------------------------------------

def cube_op_ns(op):
    if op[0] == "mmad":
        _, m, n, k = op
        pad = lambda x: -(-x // CUBE_TILE) * CUBE_TILE
        return float(pad(m) * pad(n) * pad(k)) / CUBE_MACS / CLOCK_GHZ
    if op[0] == "mmad_i8":
        _, m, n, k = op
        pad = lambda x: -(-x // CUBE_TILE) * CUBE_TILE
        return float(pad(m) * pad(n) * pad(k)) / CUBE_MACS_INT8 / CLOCK_GHZ
    if op[0] == "nop":
        return 0.0
    return None


def vector_op_ns(op):
    if op[0] == "dequant":
        return float(op[1]) * 4.0 / LANES_F16 / CLOCK_GHZ
    if op[0] == "reduce":
        _, elems, terms = op
        adds = float(elems) * float(max(terms - 1, 0))
        casts = float(elems)
        return (adds / LANES_F32 + casts / LANES_F16) / CLOCK_GHZ
    if op[0] == "cast":
        return float(op[1]) / LANES_F16 / CLOCK_GHZ
    if op[0] == "quantize_act":
        return float(op[1]) * 3.0 / LANES_F16 / CLOCK_GHZ
    if op[0] == "nop":
        return 0.0
    return None


def block_fits_l0(bm, bn, bk):
    return 2 * bm * bk * 2 <= L0A and 2 * bk * bn * 2 <= L0B and bm * bn * 4 <= L0C


def dequant_tile_fits_ub(bk, bn):
    return 2 * (bk * bn // 2 + bk * bn * 2) <= UB


# --- trace IR --------------------------------------------------------------
# Step: (compute, reads, writes, burst); reads/writes: tuple of (class, bytes).
# Phase: dict(name, unit('cube'|'vector'), steps: list[(step, run)] per engine
#   as a run-length list, pipelined, chunk).
# Trace: dict(name, phases, workspace_bytes, partial_bytes, policy)
#   policy: ('buffered',) | ('pinned', resident_bytes)


def step(compute, reads=(), writes=(), burst=0):
    return (compute, tuple(reads), tuple(writes), burst)


def phase(name, unit, runs_per_engine, pipelined, chunk=None):
    return {"name": name, "unit": unit, "engines": runs_per_engine,
            "pipelined": pipelined, "chunk": chunk}


def trace(name, phases, ws, part, policy):
    return {"name": name, "phases": phases, "workspace_bytes": ws,
            "partial_bytes": part, "policy": policy}


def phase_total_steps(ph):
    return sum(r for e in ph["engines"] for _, r in e)


def phase_active_engines(ph):
    return sum(1 for e in ph["engines"] if e)


def is_reduce(ph):
    return ph["unit"] == "vector" and ph["name"].startswith("reduce")


def is_dequant(ph):
    return ph["unit"] == "vector" and "dequant" in ph["name"]


def trace_reduce_steps(tr):
    return sum(r for ph in tr["phases"] for e in ph["engines"]
               for s, r in e if s[0][0] == "reduce")


def exposed_reduce_range(tr):
    phases = tr["phases"]
    n = len(phases)
    if n == 0:
        return None
    start = n - 1
    while start > 0 and phases[start]["pipelined"]:
        start -= 1
    if start == 0:
        return None
    if all(is_reduce(p) for p in phases[start:]):
        return (start, n)
    return None


def dequant_prologue(tr):
    if tr["phases"] and is_dequant(tr["phases"][0]):
        return 0
    return None


# --- memory.rs -------------------------------------------------------------

class Ledger:
    __slots__ = ("carried_partial_hit", "carried_weight_hit", "reserved_bytes")

    def __init__(self, carried_partial_hit=0.0, carried_weight_hit=0.0,
                 reserved_bytes=0):
        self.carried_partial_hit = carried_partial_hit
        self.carried_weight_hit = carried_weight_hit
        self.reserved_bytes = reserved_bytes

    def available_capacity(self):
        return max(L2_RETENTION * float(L2_BYTES) - float(self.reserved_bytes), 0.0)

    def attenuation(self, tr):
        cap = self.available_capacity()
        if cap <= 0.0:
            return 0.0
        if tr["policy"][0] == "pinned":
            footprint = tr["policy"][1] + tr["partial_bytes"]
        else:
            footprint = tr["workspace_bytes"] + tr["partial_bytes"]
        return max(1.0 - float(footprint) / cap, 0.0)


class L2Model:
    __slots__ = ("workspace_hit", "partial_hit", "carried_hit", "carried_weight_hit")

    def __init__(self, ws, part, carried, cweight):
        self.workspace_hit = ws
        self.partial_hit = part
        self.carried_hit = carried
        self.carried_weight_hit = cweight


def l2_with_capacity(cap, ws_bytes, part_bytes):
    def hit(b):
        if b == 0:
            return 0.0
        total = float(ws_bytes + part_bytes)
        share = cap * float(b) / total
        return min(share / float(b), 1.0)
    return L2Model(hit(ws_bytes), hit(part_bytes), 0.0, 0.0)


def l2_for_trace(tr, ledger):
    cap = ledger.available_capacity()
    if tr["policy"][0] == "buffered":
        model = l2_with_capacity(cap, tr["workspace_bytes"], tr["partial_bytes"])
    else:
        resident = tr["policy"][1]
        pinned = min(float(resident), cap)
        ws_hit = 0.0 if resident == 0 else pinned / float(resident)
        leftover = max(cap - pinned, 0.0)
        pb = tr["partial_bytes"]
        part_hit = 0.0 if pb == 0 else min(leftover / float(pb), 1.0)
        model = L2Model(ws_hit, part_hit, 0.0, 0.0)
    model.carried_hit = min(max(ledger.carried_partial_hit, 0.0), 1.0)
    model.carried_weight_hit = min(max(ledger.carried_weight_hit, 0.0), 1.0)
    return model


def read_l2_fraction(l2, cls):
    if cls == WS:
        return l2.workspace_hit
    if cls == PART:
        return l2.partial_hit
    if cls == CPART:
        return l2.carried_hit
    if cls == CWEIGHT:
        return l2.carried_weight_hit
    return 0.0


def write_split(l2, cls):
    # (l2_fraction, writeback_fraction)
    if cls == WS:
        return (1.0, 1.0 - l2.workspace_hit)
    if cls == PART:
        return (1.0, 1.0 - l2.partial_hit)
    return (1.0, 1.0)


# --- mte.rs ----------------------------------------------------------------

def burst_efficiency(burst):
    if burst == 0:
        return 1.0
    return min(float(burst) / DMA_BURST, 1.0)


def step_traffic(l2, st):
    hbm = 0.0
    l2b = 0.0
    for cls, b in st[1]:
        if b == 0:
            continue
        frac = read_l2_fraction(l2, cls)
        l2b += float(b) * frac
        hbm += float(b) * (1.0 - frac)
    for cls, b in st[2]:
        if b == 0:
            continue
        lf, wb = write_split(l2, cls)
        l2b += float(b) * lf
        hbm += float(b) * wb
    return hbm, l2b


def step_compute_ns(unit, st):
    ns = cube_op_ns(st[0]) if unit == "cube" else vector_op_ns(st[0])
    if ns is None:
        raise ValueError(f"op {st[0]} not executable on {unit}")
    return ns


class Demand:
    __slots__ = ("active", "hbm_total", "l2_total", "hbm_max", "l2_max",
                 "compute_max", "compute_total", "steps")

    def __init__(self):
        self.active = 0
        self.hbm_total = 0.0
        self.l2_total = 0.0
        self.hbm_max = 0.0
        self.l2_max = 0.0
        self.compute_max = 0.0
        self.compute_total = 0.0
        self.steps = 0


def phase_demand(l2, ph):
    d = Demand()
    d.active = phase_active_engines(ph)
    for runs in ph["engines"]:
        if not runs:
            continue
        e_hbm = 0.0
        e_l2 = 0.0
        e_compute = 0.0
        n_steps = 0
        for st, run in runs:
            hbm, l2b = step_traffic(l2, st)
            eff = burst_efficiency(st[3])
            e_hbm += hbm / eff * float(run)
            e_l2 += l2b / eff * float(run)
            e_compute += step_compute_ns(ph["unit"], st) * float(run)
            n_steps += run
        d.hbm_total += e_hbm
        d.l2_total += e_l2
        d.compute_total += e_compute
        d.hbm_max = max(d.hbm_max, e_hbm)
        d.l2_max = max(d.l2_max, e_l2)
        d.compute_max = max(d.compute_max, e_compute)
        d.steps += n_steps
    return d


def aggregate_bw(shared, active):
    return min(MTE_BW * float(max(active, 1)), shared)


def hbm_time_ns(d):
    if d.hbm_total == 0.0:
        return 0.0
    return d.hbm_total / aggregate_bw(HBM_BW, d.active)


def l2_time_ns(d):
    if d.l2_total == 0.0:
        return 0.0
    return d.l2_total / aggregate_bw(L2_BW, d.active)


# --- npu.rs ----------------------------------------------------------------

class SimReport:
    __slots__ = ("name", "total_ns", "launch_ns", "barrier_ns", "groups",
                 "phase_times", "l2", "ledger")


def build_byte_ledger(l2, phases):
    ledger = {}
    for ph in phases:
        for runs in ph["engines"]:
            for st, run in runs:
                for cls, b in st[1]:
                    if b == 0:
                        continue
                    frac = read_l2_fraction(l2, cls)
                    t = ledger.setdefault(cls, [0.0, 0.0, 0.0, 0.0])
                    t[2] += float(b * run) * frac           # l2_read
                    t[0] += float(b * run) * (1.0 - frac)   # hbm_read
                for cls, b in st[2]:
                    if b == 0:
                        continue
                    lf, wb = write_split(l2, cls)
                    t = ledger.setdefault(cls, [0.0, 0.0, 0.0, 0.0])
                    t[3] += float(b * run) * lf             # l2_write
                    t[1] += float(b * run) * wb             # hbm_write
    return ledger


def run_with_residency(tr, ledger_in=None, want_ledger=False):
    ledger_in = ledger_in or Ledger()
    l2 = l2_for_trace(tr, ledger_in)
    demands = [phase_demand(l2, ph) for ph in tr["phases"]]

    groups = []
    for i, ph in enumerate(tr["phases"]):
        if i == 0 or not ph["pipelined"]:
            groups.append([i])
        else:
            groups[-1].append(i)

    r = SimReport()
    r.name = tr["name"]
    r.phase_times = []
    r.groups = []
    total = LAUNCH_NS
    r.launch_ns = LAUNCH_NS
    r.barrier_ns = BARRIER_NS * float(max(len(groups) - 1, 0))
    total += r.barrier_ns

    for gi, group in enumerate(groups):
        g_hbm = g_l2 = g_cube = g_vector = 0.0
        for pi in group:
            d = demands[pi]
            ph = tr["phases"][pi]
            h = hbm_time_ns(d)
            l = l2_time_ns(d)
            c = d.compute_max
            g_hbm += h
            g_l2 += l
            if ph["unit"] == "cube":
                g_cube += c
            else:
                g_vector += c
            r.phase_times.append({
                "name": ph["name"], "unit": ph["unit"], "group": gi,
                "hbm_ns": h, "l2_ns": l, "compute_ns": c,
                "standalone_ns": max(h, l, c),
            })
        max_ns = max(g_hbm, g_l2, g_cube, g_vector)
        first = demands[group[0]]
        steps_per_engine = max(float(first.steps) / float(max(first.active, 1)), 1.0)
        transfer_step = (hbm_time_ns(first) + l2_time_ns(first)) / steps_per_engine
        compute_step = first.compute_max / steps_per_engine
        fill = min(transfer_step, compute_step) + EVENT_NS
        chunk_ids = [tr["phases"][pi]["chunk"] for pi in group
                     if tr["phases"][pi]["chunk"] is not None]
        rotations = float(max(chunk_ids) - min(chunk_ids)) if chunk_ids else 0.0
        g_total = max_ns + fill + EVENT_NS * rotations
        r.groups.append({
            "phases": group, "hbm_ns": g_hbm, "l2_ns": g_l2,
            "cube_ns": g_cube, "vector_ns": g_vector, "total_ns": g_total,
        })
        total += g_total

    r.total_ns = total
    r.l2 = l2
    r.ledger = build_byte_ledger(l2, tr["phases"]) if want_ledger else None
    return r


def run(tr, want_ledger=False):
    return run_with_residency(tr, None, want_ledger)


def run_merged_with(kernels, base=None):
    base = base or Ledger()
    total = 0.0
    carried = 0.0
    reports = []
    for i, tr in enumerate(kernels):
        led = Ledger(carried, base.carried_weight_hit, base.reserved_bytes)
        r = run_with_residency(tr, led)
        if i == 0:
            carried = r.l2.partial_hit
        else:
            carried *= led.attenuation(tr)
        total += r.total_ns
        reports.append(r)
    return total, reports


# --- kernels ---------------------------------------------------------------

def round_robin_counts(items, engines):
    return [len(range(e, items, engines)) for e in range(engines)]


def round_robin_steps(items, engines, k_steps, mid, last):
    """Per-engine run lists for `items` work items of k_steps steps each
    (mid x (k_steps-1) then last), mirroring kernels::round_robin_steps.
    Consecutive identical steps merge exactly as Rust's pricing loop
    groups them."""
    out = []
    for count in round_robin_counts(items, engines):
        if count == 0:
            out.append([])
            continue
        runs = []
        if k_steps == 1:
            runs.append((last, count))
        else:
            for _ in range(count):
                runs.append((mid, k_steps - 1))
                runs.append((last, 1))
        out.append(runs)
    return out


def dequant_phase(name, n, k, t, engines, pipelined, group, chunk=None):
    k_tiles = k // t["dequant_bk"]
    n_tiles = n // t["dequant_bn"]
    tiles = k_tiles * n_tiles
    elems = t["dequant_bk"] * t["dequant_bn"]
    st = step(("dequant", elems),
              reads=((WP, elems // 2),
                     (QP, 2 * (t["dequant_bk"] // group) * t["dequant_bn"] * 4)),
              writes=((WS, elems * 2),))
    runs = [[(st, c)] if c else [] for c in round_robin_counts(tiles, engines)]
    return phase(name, "vector", runs, pipelined, chunk)


def reduce_phases(m, n, t, mode):
    out_tiles = (m_padded(m) // t["bm"]) * (n // t["bn"])
    elems = t["bm"] * t["bn"]
    st = step(("reduce", elems, t["splits"]),
              reads=((PART, t["splits"] * elems * 4),),
              writes=((OUT, elems * 2),))
    engines = VEC_CORES
    counts = round_robin_counts(out_tiles, engines)
    streamable = mode == "pipelined" and out_tiles >= 2 * engines
    if not streamable:
        return [phase("reduce", "vector",
                      [[(st, c)] if c else [] for c in counts], False)]
    stream = [[(st, c - 1)] if c - 1 else [] for c in counts]
    tail = [[(st, 1)] for _ in counts]
    return [phase("reduce_stream", "vector", stream, True),
            phase("reduce_tail", "vector", tail, False)]


def splitk_schedule(p, t, mode="auto"):
    if mode == "auto":
        return resolve_reduce_auto(lambda md: splitk_schedule(p, t, md))
    m, n, k, group = p
    ks = k // t["splits"]
    k_steps = ks // t["bk"]
    p1 = dequant_phase("dequant", n, k, t, VEC_CORES, False, group)
    single = t["splits"] == 1
    items = t["splits"] * (m_padded(m) // t["bm"]) * (n // t["bn"])
    a_tile = t["bm"] * t["bk"] * 2
    b_tile = t["bk"] * t["bn"] * 2
    c_tile = t["bm"] * t["bn"] * (2 if single else 4)
    c_class = OUT if single else PART
    mid = step(("mmad", t["bm"], t["bn"], t["bk"]),
               reads=((WS, b_tile), (ACT, a_tile)), burst=t["bn"] * 2)
    last = step(("mmad", t["bm"], t["bn"], t["bk"]),
                reads=((WS, b_tile), (ACT, a_tile)),
                writes=((c_class, c_tile),), burst=t["bn"] * 2)
    p2 = phase("splitk_mmad", "cube",
               round_robin_steps(items, AI_CORES, k_steps, mid, last), True)
    if single:
        return trace(f"splitk_m{m}_n{n}_k{k}_s1", [p1, p2],
                     f16_weight_bytes(n, k), 0, ("buffered",))
    phases = [p1, p2] + reduce_phases(m, n, t, mode)
    return trace(f"splitk_m{m}_n{n}_k{k}_s{t['splits']}", phases,
                 f16_weight_bytes(n, k),
                 t["splits"] * m_padded(m) * n * 4, ("buffered",))


def chunked_schedule(p, t, mode="auto"):
    if mode == "auto":
        return resolve_reduce_auto(lambda md: chunked_schedule(p, t, md))
    m, n, k, group = p
    chunks = max(t["chunks"], 1)
    kc = k // chunks
    k_steps = (kc // t["splits"]) // t["bk"]
    single = t["splits"] == 1
    items = t["splits"] * (m_padded(m) // t["bm"]) * (n // t["bn"])
    a_tile = t["bm"] * t["bk"] * 2
    b_tile = t["bk"] * t["bn"] * 2
    c_tile = t["bm"] * t["bn"] * (2 if single else 4)
    c_class = OUT if single else PART
    mid = step(("mmad", t["bm"], t["bn"], t["bk"]),
               reads=((WS, b_tile), (ACT, a_tile)), burst=t["bn"] * 2)
    last = step(("mmad", t["bm"], t["bn"], t["bk"]),
                reads=((WS, b_tile), (ACT, a_tile)),
                writes=((c_class, c_tile),), burst=t["bn"] * 2)
    phases = []
    for c in range(chunks):
        dq = dequant_phase("chunk_dequant", n, kc, t, VEC_CORES, c > 0, group, c)
        phases.append(dq)
        tail = last if c == chunks - 1 else mid
        phases.append(phase("chunk_mmad", "cube",
                            round_robin_steps(items, AI_CORES, k_steps, mid, tail),
                            True, c))
    if not single:
        phases += reduce_phases(m, n, t, mode)
    slice_bytes = kc * n * 2
    resident = slice_bytes * min(chunks, 2)
    if chunks > 1:
        ws, policy = resident, ("pinned", resident)
    else:
        ws, policy = f16_weight_bytes(n, k), ("buffered",)
    return trace(f"chunked_m{m}_n{n}_k{k}_s{t['splits']}_c{chunks}", phases, ws,
                 0 if single else t["splits"] * m_padded(m) * n * 4, policy)


def dp_schedule(p, t):
    m, n, k, group = p
    assert t["splits"] == 1
    strips = (m_padded(m) // t["bm"]) * (n // t["bn"])
    active = min(strips, AI_CORES)
    p1 = dequant_phase("dequant", n, k, t,
                       min(active * VEC_PER_CORE, VEC_CORES), False, group)
    k_steps = k // t["bk"]
    a_tile = t["bm"] * t["bk"] * 2
    b_tile = t["bk"] * t["bn"] * 2
    out_tile = t["bm"] * t["bn"] * 2
    mid = step(("mmad", t["bm"], t["bn"], t["bk"]),
               reads=((WS, b_tile), (ACT, a_tile)), burst=t["bn"] * 2)
    last = step(("mmad", t["bm"], t["bn"], t["bk"]),
                reads=((WS, b_tile), (ACT, a_tile)),
                writes=((OUT, out_tile),), burst=t["bn"] * 2)
    p2 = phase("dp_mmad", "cube",
               round_robin_steps(strips, AI_CORES, k_steps, mid, last), True)
    return trace(f"dp_m{m}_n{n}_k{k}", [p1, p2], f16_weight_bytes(n, k), 0,
                 ("buffered",))


def fp16_schedule(p, t):
    m, n, k, _ = p
    assert t["splits"] == 1
    strips = (m_padded(m) // t["bm"]) * (n // t["bn"])
    k_steps = k // t["bk"]
    a_tile = t["bm"] * t["bk"] * 2
    b_tile = t["bk"] * t["bn"] * 2
    out_tile = t["bm"] * t["bn"] * 2
    mid = step(("mmad", t["bm"], t["bn"], t["bk"]),
               reads=((WF16, b_tile), (ACT, a_tile)), burst=t["bn"] * 2)
    last = step(("mmad", t["bm"], t["bn"], t["bk"]),
                reads=((WF16, b_tile), (ACT, a_tile)),
                writes=((OUT, out_tile),), burst=t["bn"] * 2)
    ph = phase("fp16_mmad", "cube",
               round_robin_steps(strips, AI_CORES, k_steps, mid, last), False)
    return trace(f"fp16_m{m}_n{n}_k{k}", [ph], 0, 0, ("buffered",))


def fused_schedule(p, t):
    m, n, k, group = p
    ks = k // t["splits"]
    k_steps = ks // t["bk"]
    single = t["splits"] == 1
    items = t["splits"] * (m_padded(m) // t["bm"]) * (n // t["bn"])
    a_tile = t["bm"] * t["bk"] * 2
    b_packed = t["bk"] * t["bn"] // 2
    qparam = 2 * max(t["bk"] // group, 1) * t["bn"] * 4
    c_tile = t["bm"] * t["bn"] * (2 if single else 4)
    c_class = OUT if single else PART
    mid = step(("mmad", t["bm"], t["bn"], t["bk"]),
               reads=((WP, b_packed + qparam), (ACT, a_tile)))
    last = step(("mmad", t["bm"], t["bn"], t["bk"]),
                reads=((WP, b_packed + qparam), (ACT, a_tile)),
                writes=((c_class, c_tile),))
    p1 = phase("fused_mmad", "cube",
               round_robin_steps(items, AI_CORES, k_steps, mid, last), False)
    if single:
        return trace(f"fused_m{m}_n{n}_k{k}_s1", [p1], 0, 0, ("buffered",))
    out_tiles = (m_padded(m) // t["bm"]) * (n // t["bn"])
    elems = t["bm"] * t["bn"]
    rstep = step(("reduce", elems, t["splits"]),
                 reads=((PART, t["splits"] * elems * 4),),
                 writes=((OUT, elems * 2),))
    runs = [[(rstep, c)] if c else []
            for c in round_robin_counts(out_tiles, VEC_CORES)]
    p2 = phase("reduce", "vector", runs, False)
    return trace(f"fused_m{m}_n{n}_k{k}_s{t['splits']}", [p1, p2], 0,
                 t["splits"] * m_padded(m) * n * 4, ("buffered",))


def resolve_reduce_auto(build):
    pipelined = build("pipelined")
    if not any(ph["name"] == "reduce_stream" for ph in pipelined["phases"]):
        return pipelined
    barrier = build("barrier")
    p_ns = run(pipelined).total_ns
    b_ns = run(barrier).total_ns
    return pipelined if p_ns <= b_ns else barrier


# --- tiling.rs -------------------------------------------------------------

def tiling(bm, bn, bk, splits, chunks, dq_bk, dq_bn, rebalance=0):
    return {"bm": bm, "bn": bn, "bk": bk, "splits": splits, "chunks": chunks,
            "dequant_bk": dq_bk, "dequant_bn": dq_bn, "rebalance": rebalance}


def tiling_validate(t, p):
    m, n, k, group = p
    mp = m_padded(m)
    if not block_fits_l0(t["bm"], t["bn"], t["bk"]):
        return False
    if not dequant_tile_fits_ub(t["dequant_bk"], t["dequant_bn"]):
        return False
    if t.get("rebalance", 0) > 100:
        return False
    if k % t["splits"] != 0:
        return False
    ks = k // t["splits"]
    if ks % t["bk"] != 0 or mp % t["bm"] != 0 or n % t["bn"] != 0:
        return False
    if t["dequant_bk"] % group != 0:
        return False
    if k % t["dequant_bk"] != 0 or n % t["dequant_bn"] != 0:
        return False
    if t["chunks"] < 1:
        return False
    if t["chunks"] > 1:
        if k % t["chunks"] != 0:
            return False
        kc = k // t["chunks"]
        if kc % t["splits"] != 0:
            return False
        if (kc // t["splits"]) % t["bk"] != 0:
            return False
        if kc % t["dequant_bk"] != 0:
            return False
    return True


def pow2_divisor(n, cap, floor):
    b = cap
    while b > floor and n % b != 0:
        b //= 2
    return b


def phase2_cost(p, t):
    m, n, k, _ = p
    mp = m_padded(m)
    items = t["splits"] * (mp // t["bm"]) * (n // t["bn"])
    active = float(max(min(items, AI_CORES), 1))
    agg = lambda shared: min(MTE_BW * active, shared)
    ws_bytes = float(f16_weight_bytes(n, k)) * float(mp // t["bm"])
    a_bytes = float(items) * float(t["bm"] * (k // t["splits"]) * 2)
    partial_bytes = float(t["splits"] * mp * n * 4 * 2)
    eff = min(float(t["bn"]) * 2.0 / DMA_BURST, 1.0)
    t_l2 = ws_bytes / eff / agg(L2_BW)
    t_hbm = (a_bytes / eff + partial_bytes) / agg(HBM_BW)
    sync = BARRIER_NS if t["splits"] > 1 else 0.0
    return max(t_l2, t_hbm) + sync


def fit_bk(bm, bn, bk):
    while not block_fits_l0(bm, bn, bk) and bk > 16:
        bk //= 2
    return bk


def select_splitk(p):
    m, n, k, group = p
    mp = m_padded(m)
    bm = pow2_divisor(mp, 64, 16)
    m_tiles = mp // bm
    best = None  # (score, tiling)
    for bn in (256, 128, 64, 32, 16):
        if n % bn != 0:
            continue
        bk = min(group, k)
        while not block_fits_l0(bm, bn, bk) and bk > 16:
            bk //= 2
        n_tiles = n // bn
        base = n_tiles * m_tiles
        splits = 1
        while True:
            t = tiling(bm, bn, bk, splits, 1, group, pow2_divisor(n, 256, 16))
            if tiling_validate(t, p):
                score = phase2_cost(p, t)
                if best is None:
                    better = True
                else:
                    bscore, bt = best
                    better = score < bscore * 0.95 or (score <= bscore and bn > bt["bn"])
                if better:
                    best = (score, t)
            if (splits * base >= AI_CORES or k % (2 * splits) != 0
                    or (k // (2 * splits)) % group != 0
                    or (k // (2 * splits)) % bk != 0):
                break
            splits *= 2
    assert best is not None, f"no legal splitk tiling for {p}"
    return best[1]


def select_fp16(p):
    m, n, k, group = p
    mp = m_padded(m)
    best = None
    for bn in (256, 128, 64, 32, 16):
        if n % bn != 0:
            continue
        for bm in (128, 64, 32, 16):
            if mp % bm != 0:
                continue
            bk = min(group, k)
            while not block_fits_l0(bm, bn, bk) and bk > 16:
                bk //= 2
            t = tiling(bm, bn, bk, 1, 1, group, pow2_divisor(n, 256, 16))
            if not tiling_validate(t, p):
                continue
            strips = (mp // bm) * (n // bn)
            active = float(max(min(strips, AI_CORES), 1))
            weight_bytes = float(f16_weight_bytes(n, k)) * float(mp // bm)
            t_hbm = weight_bytes / min(MTE_BW * active, HBM_BW)
            t_compute = (float(macs(m, n, k)) / CUBE_MACS) / CLOCK_GHZ / active
            score = max(t_hbm, t_compute)
            if best is None:
                better = True
            else:
                bscore, bt = best
                better = score < bscore * 0.98 or (
                    score <= bscore and bn + bm > bt["bn"] + bt["bm"])
            if better:
                best = (score, t)
    assert best is not None
    return best[1]


def select_data_parallel(p):
    m, n, k, group = p
    mp = m_padded(m)
    bn = pow2_divisor(n, 256, 16)
    bk = group
    while not block_fits_l0(16, bn, bk) and bk > 16:
        bk //= 2
    bm = pow2_divisor(mp, 128, 16)
    t = tiling(bm, bn, bk, 1, 1, group, pow2_divisor(n, 256, 16))
    assert tiling_validate(t, p)
    return t


def select_chunked(p):
    m, n, k, group = p
    base = select_splitk(p)
    budget = L2_RETENTION * float(L2_BYTES)
    resident = lambda c: float((k // c) * n * 2 * min(c, 2))
    if resident(1) <= budget:
        return base
    legal = lambda c: tiling_validate(dict(base, chunks=c), p)
    max_chunks = min(k // base["dequant_bk"], 64)
    fit = None
    deepest = 1
    for c in range(2, max_chunks + 1):
        if not legal(c):
            continue
        deepest = c
        if resident(c) <= budget:
            fit = c
            break
    candidate = fit if fit is not None else deepest
    if candidate == 1:
        return base
    mono = base
    chunky = dict(base, chunks=candidate)
    mono_ns = run(chunked_schedule(p, mono)).total_ns
    chunky_ns = run(chunked_schedule(p, chunky)).total_ns
    return chunky if chunky_ns <= mono_ns else mono


STRATEGIES = ("splitk", "data_parallel", "fp16_native", "fused", "chunked")


def select_tiling(p, strategy):
    if strategy in ("splitk", "fused"):
        return select_splitk(p)
    if strategy == "data_parallel":
        return select_data_parallel(p)
    if strategy == "fp16_native":
        return select_fp16(p)
    if strategy == "chunked":
        return select_chunked(p)
    raise ValueError(strategy)


def schedule_with_reduce(p, strategy, t, mode="auto"):
    if strategy == "splitk":
        return splitk_schedule(p, t, mode)
    if strategy == "data_parallel":
        return dp_schedule(p, t)
    if strategy == "fp16_native":
        return fp16_schedule(p, t)
    if strategy == "fused":
        return fused_schedule(p, t)
    if strategy == "chunked":
        return chunked_schedule(p, t, mode)
    raise ValueError(strategy)


def schedule(p, strategy):
    return schedule_with_reduce(p, strategy, select_tiling(p, strategy))


# --- kernels/w4a8.rs -------------------------------------------------------
#
# Problems in this mirror are bare (m, n, k, group) tuples with no
# precision tag, so the W4A8 family lives behind its own entry points
# (`select_w4a8`, `w4a8_schedule`, `tune_search_w4a8`) — exactly the
# split the Rust side enforces with `Precision::W4A8` tagging: untagged
# searches never see these functions, tagged searches add them on top
# of the five precision-agnostic strategies.

def deferred_tiles(tiles, rebalance):
    return tiles * rebalance // 100


def w4a8_weight_convert_phase(p, t):
    _, n, k, group = p
    k_tiles = k // t["dequant_bk"]
    n_tiles = n // t["dequant_bn"]
    tiles = k_tiles * n_tiles
    deferred = deferred_tiles(tiles, t["rebalance"])
    elems = t["dequant_bk"] * t["dequant_bn"]
    param_bytes = 2 * (t["dequant_bk"] // group) * t["dequant_bn"] * 4
    reads = ((WP, elems // 2), (QP, param_bytes))
    writes = ((WS, elems),)
    full_step = step(("dequant", elems), reads=reads, writes=writes)
    deferred_step = step(("cast", elems), reads=reads, writes=writes)
    # Tiles [0, deferred) defer; round-robin gives engine e the items
    # e, e+E, e+2E, ..., so its deferred prefix has len(range(e,
    # deferred, E)) steps and the pricing loop merges each kind into
    # one run.
    engines = VEC_CORES
    runs_per_engine = []
    for e in range(engines):
        count = len(range(e, tiles, engines))
        d = len(range(e, deferred, engines))
        runs = []
        if d:
            runs.append((deferred_step, d))
        if count - d:
            runs.append((full_step, count - d))
        runs_per_engine.append(runs)
    return phase("w4a8_dequant", "vector", runs_per_engine, False)


def w4a8_act_quant_phase(p, t):
    m, _, k, _ = p
    rows = m_padded(m) // 16
    tiles = rows * (k // t["dequant_bk"])
    elems = 16 * t["dequant_bk"]
    st = step(("quantize_act", elems),
              reads=((ACT, elems * 2),), writes=((WS, elems),))
    runs = [[(st, c)] if c else []
            for c in round_robin_counts(tiles, VEC_CORES)]
    return phase("act_quant", "vector", runs, True)


def w4a8_reduce_scale_phase(p, t, pipelined_with_prev):
    m, n, k, group = p
    k_tiles = k // t["dequant_bk"]
    n_tiles = n // t["dequant_bn"]
    deferred = deferred_tiles(k_tiles * n_tiles, t["rebalance"])
    if deferred == 0:
        return None
    mp = m_padded(m)
    elems = mp * t["dequant_bn"] * (t["dequant_bk"] // group)
    st = step(("cast", elems),
              reads=((OUT, mp * t["dequant_bn"] * 2),
                     (QP, 2 * (t["dequant_bk"] // group) * t["dequant_bn"] * 4)),
              writes=((OUT, mp * t["dequant_bn"] * 2),))
    runs = [[(st, c)] if c else []
            for c in round_robin_counts(deferred, VEC_CORES)]
    return phase("reduce_scale", "vector", runs, pipelined_with_prev)


def w4a8_schedule(p, t, mode="auto"):
    if mode == "auto":
        return resolve_reduce_auto(lambda md: w4a8_schedule(p, t, md))
    m, n, k, group = p
    ks = k // t["splits"]
    k_steps = ks // t["bk"]
    p1 = w4a8_weight_convert_phase(p, t)
    p2 = w4a8_act_quant_phase(p, t)
    single = t["splits"] == 1
    items = t["splits"] * (m_padded(m) // t["bm"]) * (n // t["bn"])
    a_tile = t["bm"] * t["bk"]   # INT8 activations
    b_tile = t["bk"] * t["bn"]   # INT8 weights
    c_tile = t["bm"] * t["bn"] * (2 if single else 4)
    c_class = OUT if single else PART
    mid = step(("mmad_i8", t["bm"], t["bn"], t["bk"]),
               reads=((WS, b_tile), (WS, a_tile)), burst=t["bn"])
    last = step(("mmad_i8", t["bm"], t["bn"], t["bk"]),
                reads=((WS, b_tile), (WS, a_tile)),
                writes=((c_class, c_tile),), burst=t["bn"])
    p3 = phase("w4a8_mmad", "cube",
               round_robin_steps(items, AI_CORES, k_steps, mid, last), True)
    phases = [p1, p2, p3]
    if not single:
        phases += reduce_phases(m, n, t, mode)
    scale = w4a8_reduce_scale_phase(p, t, not single)
    if scale is not None:
        phases.append(scale)
    ws = k * n + m_padded(m) * k
    part = 0 if single else t["splits"] * m_padded(m) * n * 4
    return trace(f"w4a8_m{m}_n{n}_k{k}_s{t['splits']}", phases, ws, part,
                 ("buffered",))


def select_w4a8(p):
    base = select_splitk(p)
    best = None
    for rebalance in (0, 50, 100):
        t = dict(base, rebalance=rebalance)
        ns = run(w4a8_schedule(p, t)).total_ns
        if best is None or ns < best[0]:
            best = (ns, t)
    assert best is not None, f"no legal w4a8 tiling for {p}"
    return best[1]


# --- tune/search.rs --------------------------------------------------------

def search_candidates(p, strategy):
    try:
        base = select_tiling(p, strategy)
    except AssertionError:
        return []
    out = [base]

    def push(t):
        if t not in out:
            out.append(t)

    _, n, k, group = p
    if strategy in ("splitk", "fused", "chunked"):
        if base["splits"] > 1:
            push(dict(base, splits=base["splits"] // 2))
        push(dict(base, splits=base["splits"] * 2))
    if strategy == "chunked":
        if base["chunks"] > 1:
            push(dict(base, chunks=base["chunks"] // 2))
            push(dict(base, chunks=1))
        push(dict(base, chunks=base["chunks"] * 2))
    for bn in (256, 128, 64):
        if bn == base["bn"] or n % bn != 0:
            continue
        bk = fit_bk(base["bm"], bn, min(group, k))
        push(dict(base, bn=bn, bk=bk))
    if base["bm"] > 16:
        push(dict(base, bm=base["bm"] // 2))
    for dq_bn in (256, 128, 64):
        if dq_bn == base["dequant_bn"] or n % dq_bn != 0:
            continue
        push(dict(base, dequant_bn=dq_bn))
    return out


def tune_search(p):
    scored = []
    for strategy in STRATEGIES:
        for t in search_candidates(p, strategy):
            if not tiling_validate(t, p):
                continue
            try:
                tr = schedule_with_reduce(p, strategy, t)
            except AssertionError:
                continue
            scored.append((strategy, t, run(tr).total_ns))
    assert scored, f"no legal schedule for {p}"
    scored.sort(key=lambda e: e[2])
    return scored[0]


def w4a8_search_candidates(p):
    """Mirror of tune/search.rs `candidates` for Strategy::W4A8 on a
    W4A8-tagged problem (pushed in the Rust neighborhood order so
    stable-sort ties resolve identically)."""
    try:
        base = select_w4a8(p)
    except AssertionError:
        return []
    out = [base]

    def push(t):
        if t not in out:
            out.append(t)

    _, n, k, group = p
    if base["splits"] > 1:
        push(dict(base, splits=base["splits"] // 2))
    push(dict(base, splits=base["splits"] * 2))
    for bn in (256, 128, 64):
        if bn == base["bn"] or n % bn != 0:
            continue
        bk = fit_bk(base["bm"], bn, min(group, k))
        push(dict(base, bn=bn, bk=bk))
    if base["bm"] > 16:
        push(dict(base, bm=base["bm"] // 2))
    for dq_bn in (256, 128, 64):
        if dq_bn == base["dequant_bn"] or n % dq_bn != 0:
            continue
        push(dict(base, dequant_bn=dq_bn))
    for rebalance in (0, 50, 100):
        if rebalance != base["rebalance"]:
            push(dict(base, rebalance=rebalance))
    return out


def tune_search_w4a8(p):
    """Mirror of tune::search on a W4A8-tagged problem: the five
    precision-agnostic strategies keep their exact W4A16 candidate sets
    (their tilers ignore the tag), and the w4a8 family lands on top —
    the strict-superset construction behind Auto-never-slower."""
    scored = []
    for strategy in STRATEGIES:
        for t in search_candidates(p, strategy):
            if not tiling_validate(t, p):
                continue
            try:
                tr = schedule_with_reduce(p, strategy, t)
            except AssertionError:
                continue
            scored.append((strategy, t, run(tr).total_ns))
    for t in w4a8_search_candidates(p):
        if not tiling_validate(t, p):
            continue
        scored.append(("w4a8", t, run(w4a8_schedule(p, t)).total_ns))
    assert scored, f"no legal schedule for {p}"
    scored.sort(key=lambda e: e[2])
    return scored[0]


class Tuner:
    def __init__(self):
        self.cache = {}

    def key(self, p):
        m, n, k, group = p
        return (m_padded(m), n, k, group)

    def resolve(self, p):
        key = self.key(p)
        if key not in self.cache:
            self.cache[key] = tune_search(p)
        return self.cache[key]


# --- coschedule.rs ---------------------------------------------------------

def carry_step(st):
    reads = tuple((CPART if cls == PART and b > 0 else cls, b)
                  for cls, b in st[1])
    return (st[0], reads, st[2], st[3])


def merge_runs(runs):
    """Merge adjacent equal-step runs — the Rust pricing loop groups a
    flat step list maximally, so concatenated run lists must re-merge to
    keep the float accumulation order identical."""
    out = []
    for st, r in runs:
        if out and out[-1][0] == st:
            out[-1] = (st, out[-1][1] + r)
        else:
            out.append((st, r))
    return out


def splice(producer, consumer):
    rng = exposed_reduce_range(producer)
    dq = dequant_prologue(consumer)
    if rng is None or dq is None:
        return None
    start, end = rng
    head = dict(producer, name=producer["name"] + "_head",
                phases=producer["phases"][:start])
    carried = []
    for ph in producer["phases"][start:end]:
        if len(ph["engines"]) > len(carried):
            carried += [[] for _ in range(len(ph["engines"]) - len(carried))]
        for e, runs in enumerate(ph["engines"]):
            carried[e] += [(carry_step(s), r) for s, r in runs]
    new_phases = [dict(p) for p in consumer["phases"]]
    proto = new_phases[dq]
    engines = [list(r) for r in proto["engines"]]
    if len(carried) > len(engines):
        engines += [[] for _ in range(len(carried) - len(engines))]
    for e, runs in enumerate(carried):
        if runs:
            engines[e] = merge_runs(runs + engines[e])
    proto = dict(proto, name="spliced_dequant", engines=engines)
    new_phases[dq] = proto
    spliced = dict(consumer, name=consumer["name"] + "_spliced",
                   phases=new_phases)
    return {"name": f"merged_{producer['name']}__{consumer['name']}",
            "kernels": [head, spliced]}


def pair_decision_with(producer, consumer, sequential_ns, base=None):
    merged = splice(producer, consumer)
    if merged is None:
        return None
    merged_ns, _ = run_merged_with(merged["kernels"], base)
    return (sequential_ns, merged_ns, max(sequential_ns - merged_ns, 0.0))


def exposed_tail_steps(producer):
    rng = exposed_reduce_range(producer)
    if rng is None:
        return 0
    return sum(phase_total_steps(p) for p in producer["phases"][rng[0]:rng[1]])


def prologue_steps(consumer):
    dq = dequant_prologue(consumer)
    if dq is None:
        return 0
    return phase_total_steps(consumer["phases"][dq])


def saturates(producer, consumer):
    tail = exposed_tail_steps(producer)
    return tail > 0 and tail > prologue_steps(consumer)


def distribute_balanced(proto, carried_steps, vec_engines):
    """carried_steps: flat list of steps (not run-length)."""
    if not carried_steps:
        return proto
    engines = [list(r) for r in proto["engines"]]
    slots = max(vec_engines, len(engines))
    engines += [[] for _ in range(slots - len(engines))]
    load = [sum(r for _, r in e) for e in engines]
    assigned = [[] for _ in range(slots)]
    for st in carried_steps:
        e = min(range(slots), key=lambda i: (load[i], i))
        load[e] += 1
        assigned[e].append(st)
    for e in range(slots):
        if not assigned[e]:
            continue
        runs = []
        for st in assigned[e]:
            if runs and runs[-1][0] == st:
                runs[-1] = (st, runs[-1][1] + 1)
            else:
                runs.append((st, 1))
        engines[e] = merge_runs(runs + engines[e])
    return dict(proto, name="spliced_dequant", engines=engines)


def splice_chain(vec_engines, producer, first, second):
    rng = exposed_reduce_range(producer)
    dq1 = dequant_prologue(first)
    dq2 = dequant_prologue(second)
    if rng is None or dq1 is None or dq2 is None:
        return None
    start, end = rng
    head = dict(producer, name=producer["name"] + "_head",
                phases=producer["phases"][:start])
    carried = []
    for ph in producer["phases"][start:end]:
        for runs in ph["engines"]:
            for st, r in runs:
                carried += [carry_step(st)] * r
    cap1 = min(prologue_steps(first), len(carried))
    to_first, to_second = carried[:cap1], carried[cap1:]
    s1_phases = [dict(p) for p in first["phases"]]
    s1_phases[dq1] = distribute_balanced(s1_phases[dq1], to_first, vec_engines)
    s1 = dict(first, name=first["name"] + "_spliced", phases=s1_phases)
    s2_phases = [dict(p) for p in second["phases"]]
    s2_phases[dq2] = distribute_balanced(s2_phases[dq2], to_second, vec_engines)
    s2 = dict(second, name=second["name"] + "_spliced2", phases=s2_phases)
    return {"name": f"chain_{producer['name']}__{first['name']}__{second['name']}",
            "kernels": [head, s1, s2]}


def chain_decision(producer, first, second, sequential_ns):
    merged = splice_chain(VEC_CORES, producer, first, second)
    if merged is None:
        return None
    merged_ns, _ = run_merged_with(merged["kernels"])
    return (sequential_ns, merged_ns, max(sequential_ns - merged_ns, 0.0))


# --- residency.rs ----------------------------------------------------------

def weight_footprint_bytes(p):
    _, n, k, group = p
    return packed_weight_bytes(n, k) + 2 * (k // group) * n * 4


def pin_budget_bytes():
    return int(L2_RETENTION * float(L2_BYTES))


def carry_weights(tr):
    phases = []
    for ph in tr["phases"]:
        engines = []
        for runs in ph["engines"]:
            new_runs = []
            for st, r in runs:
                reads = tuple((CWEIGHT if cls in (WP, QP) and b > 0 else cls, b)
                              for cls, b in st[1])
                new_runs.append(((st[0], reads, st[2], st[3]), r))
            engines.append(new_runs)
        phases.append(dict(ph, engines=engines))
    return dict(tr, name=tr["name"] + "_resident", phases=phases)


def packed_read_bytes(tr):
    return sum(b * r for ph in tr["phases"] for e in ph["engines"]
               for st, r in e for cls, b in st[1] if cls in (WP, QP))


def price_pins(inputs, pins, extra_ns, price_exact):
    pinned_bytes = sum(inst * ub for _, inst, ub in pins)
    ledger = Ledger(0.0, 1.0, pinned_bytes)
    by_node = {node: inst for node, inst, _ in pins}
    cold = []       # per node: (trace, unit_ns) or None
    resident = []   # per node: (trace, unit_ns) or None
    pinned = []
    total = extra_ns
    for i, inp in enumerate(inputs):
        count = max(inp["count"], 1)
        p = min(by_node.get(i, 0), count)
        if p < count:
            ns = run_with_residency(inp["trace"], ledger).total_ns
            c = (inp["trace"], ns)
        else:
            c = None
        if p > 0:
            carried = carry_weights(inp["trace"])
            ns = run_with_residency(carried, ledger).total_ns
            r = (carried, ns)
        else:
            r = None
        total += (float(p) * (r[1] if r is not None else 0.0)
                  + float(count - p) * (c[1] if c is not None else 0.0))
        cold.append(c)
        resident.append(r)
        pinned.append(p)
    if price_exact:
        gain = 0.0
        for i, inp in enumerate(inputs):
            count = max(inp["count"], 1)
            if count < 2:
                continue
            # Resident instances first: p-1 resident pairs, count-p-1 cold
            # pairs, the one mixed adjacency contributes nothing.
            p = pinned[i]
            if p > 1:
                rt, rns = resident[i]
                d = pair_decision_with(rt, rt, 2.0 * rns, ledger)
                if d is not None:
                    gain += float(p - 1) * d[2]
            if count - p > 1:
                ct, cns = cold[i]
                d = pair_decision_with(ct, ct, 2.0 * cns, ledger)
                if d is not None:
                    gain += float(count - p - 1) * d[2]
        boundary = lambda i: cold[i] if cold[i] is not None else resident[i]
        for i in range(1, len(inputs)):
            pt, pns = boundary(i - 1)
            ct, cns = boundary(i)
            d = pair_decision_with(pt, ct, pns + cns, ledger)
            if d is not None:
                gain += d[2]
        total -= gain
    return total


def plan_nodes(inputs, extra_ns, price_exact):
    import functools
    budget = pin_budget_bytes()
    candidates = []
    for i, inp in enumerate(inputs):
        if packed_read_bytes(inp["trace"]) == 0:
            continue
        unit_bytes = weight_footprint_bytes(inp["problem"])
        if unit_bytes == 0 or unit_bytes > budget:
            continue
        ledger = Ledger(0.0, 1.0, unit_bytes)
        resident_ns = run_with_residency(carry_weights(inp["trace"]), ledger).total_ns
        density = (inp["unit_ns"] - resident_ns) / float(unit_bytes)
        if density > 0.0:
            candidates.append((i, unit_bytes, density))

    def cmp(a, b):
        if a[2] != b[2]:
            return -1 if b[2] < a[2] else 1
        return -1 if a[0] < b[0] else (1 if a[0] > b[0] else 0)

    candidates.sort(key=functools.cmp_to_key(cmp))
    pins = []
    pinned_bytes = 0
    for node, unit_bytes, _ in candidates:
        room = (budget - pinned_bytes) // unit_bytes
        instances = min(inputs[node]["count"], room)
        if instances == 0:
            continue
        pinned_bytes += instances * unit_bytes
        pins.append((node, instances, unit_bytes))
    baseline_ns = price_pins(inputs, [], extra_ns, price_exact)
    best_ns = baseline_ns
    best_len = 0
    for ln in range(1, len(pins) + 1):
        ns = price_pins(inputs, pins[:ln], extra_ns, price_exact)
        if ns < best_ns:
            best_ns = ns
            best_len = ln
    pins = pins[:best_len]
    return {"pins": pins,
            "pinned_bytes": sum(inst * ub for _, inst, ub in pins),
            "budget_bytes": budget,
            "resident_ns": best_ns,
            "baseline_ns": baseline_ns,
            "gain_ns": max(baseline_ns - best_ns, 0.0)}


# --- vecpass.rs + decode step graph ---------------------------------------

def price_pass(elems, ops_per_elem, hbm_bytes, l2_bytes):
    engines = max(VEC_CORES, 1)
    per_engine = float(elems) / float(engines)
    compute_ns = per_engine * ops_per_elem / LANES_F16 / CLOCK_GHZ
    hbm_ns = 0.0 if hbm_bytes == 0 else float(hbm_bytes) / aggregate_bw(HBM_BW, engines)
    l2_ns = 0.0 if l2_bytes == 0 else float(l2_bytes) / aggregate_bw(L2_BW, engines)
    return max(compute_ns, hbm_ns, l2_ns) + BARRIER_NS


def moe_active(experts, topk, batch):
    pairs = batch * topk
    return max(min(experts, pairs), 1)


def moe_tokens(experts, topk, batch):
    pairs = batch * topk
    active = moe_active(experts, topk, batch)
    return -(-pairs // active)


def step_nodes(batch, kv_len, heads, hidden, ffn, kv, group, moe=None):
    """Mirror of DecodeStep::nodes: list of ('gemm', kind, problem, count)
    and ('vector', kind, elems, ops_per_elem(float), hbm, l2)."""
    m, h = batch, hidden
    head_dim = float(hidden) / float(heads)
    scores = m * heads * kv_len
    norm = ("vector", "rmsnorm", m * h, 6.0, 0, 2 * m * h * 2)
    residual = ("vector", "residual", m * h, 1.0, 0, 3 * m * h * 2)
    nodes = [
        norm,
        ("gemm", "qkv", (m, h + 2 * kv, h, group), 1),
        ("vector", "attn_score", scores, 2.0 * head_dim,
         m * kv_len * kv * 2, m * h * 2 + scores * 2),
        ("vector", "attn_softmax", scores, 8.0, 0, 2 * scores * 2),
        ("vector", "attn_av", scores, 2.0 * head_dim,
         m * kv_len * kv * 2, scores * 2 + m * h * 2),
        ("gemm", "attn_out", (m, h, h, group), 1),
        residual,
        norm,
    ]
    if moe is None:
        nodes += [
            ("gemm", "up_gate", (m, 2 * ffn, h, group), 1),
            ("vector", "activation", m * ffn, 4.0, 0, 3 * m * ffn * 2),
            ("gemm", "down", (m, h, ffn, group), 1),
        ]
    else:
        experts, topk, ef = moe
        active = moe_active(experts, topk, m)
        tokens = moe_tokens(experts, topk, m)
        routed = active * tokens
        nodes += [
            ("vector", "moe_route", m * experts, 2.0 * float(h) + 8.0,
             h * experts * 2, m * h * 2 + m * experts * 2),
            ("gemm", "moe_expert", (tokens, 2 * ef, h, group), active),
            ("vector", "activation", routed * ef, 4.0, 0, 3 * routed * ef * 2),
            ("gemm", "moe_expert", (tokens, h, ef, group), active),
        ]
    nodes.append(residual)
    return nodes


# --- analysis/layer.rs -----------------------------------------------------

def overlap_terms(r):
    reduce_tail = 0.0
    if len(r.groups) > 1:
        g = r.groups[-1]
        if all(r.phase_times[pi]["name"].startswith("reduce") for pi in g["phases"]):
            reduce_tail = g["total_ns"]
    dequant_slack = 0.0
    for pt in r.phase_times:
        if "dequant" in pt["name"]:
            dequant_slack = max(pt["standalone_ns"] - pt["compute_ns"], 0.0)
            break
    return reduce_tail, dequant_slack


def simulate_gemm_node(problem, count, strategy, t):
    served = schedule_with_reduce(problem, strategy, t, "auto")
    r = run(served)
    unit_ns = r.total_ns
    reduce_tail, slack = overlap_terms(r)
    if strategy in ("splitk", "chunked"):
        barrier = schedule_with_reduce(problem, strategy, t, "barrier")
        unit_barrier = run(barrier).total_ns
    else:
        unit_barrier = unit_ns
    return {"problem": problem, "count": max(count, 1), "strategy": strategy,
            "unit_ns": unit_ns, "unit_barrier_ns": unit_barrier,
            "total_ns": unit_ns * float(max(count, 1)),
            "barrier_ns": unit_barrier * float(max(count, 1)),
            "reduce_tail_ns": reduce_tail, "dequant_slack_ns": slack,
            "trace": served}


def build_ledger_pairs(nodes, price_exact):
    """nodes: mixed list; gemm entries are dicts from simulate_gemm_node
    (with an extra 'index' into the step list)."""
    gemms = [(i, n) for i, n in enumerate(nodes) if isinstance(n, dict)]
    ledger = []

    def push(pi, p, ci, c, pairs):
        gain = min(p["reduce_tail_ns"], c["dequant_slack_ns"])
        exact = None
        if price_exact:
            exact = pair_decision_with(p["trace"], c["trace"],
                                       p["unit_ns"] + c["unit_ns"])
        if gain > 0.0 or (exact is not None and exact[2] > 0.0):
            ledger.append({"producer": pi, "consumer": ci, "pairs": pairs,
                           "gain_ns": gain, "exact": exact, "chain": None,
                           "superseded": False})

    for i, g in gemms:
        if g["count"] > 1:
            push(i, g, i, g, g["count"] - 1)
    for (ai, a), (bi, b) in zip(gemms, gemms[1:]):
        push(ai, a, bi, b, 1)

    if price_exact:
        for w in range(len(gemms) - 2):
            (ai, a), (bi, b), (ci, c) = gemms[w], gemms[w + 1], gemms[w + 2]
            # Chains only over single-instance nodes (an expert batch in
            # the middle would run count-1 more instances between the
            # spliced consumers than the 3-kernel simulation prices).
            if a["count"] != 1 or b["count"] != 1 or c["count"] != 1:
                continue
            if not saturates(a["trace"], b["trace"]):
                continue

            def pos(p, q):
                for idx, e in enumerate(ledger):
                    if e["producer"] == p and e["consumer"] == q:
                        return idx
                return None

            first = pos(ai, bi)
            if first is not None and (ledger[first]["chain"] is not None
                                      or ledger[first]["superseded"]):
                continue
            second = pos(bi, ci)
            if second is not None and (ledger[second]["chain"] is not None
                                       or ledger[second]["superseded"]):
                continue
            sequential = a["unit_ns"] + b["unit_ns"] + c["unit_ns"]
            decision = chain_decision(a["trace"], b["trace"], c["trace"], sequential)
            if decision is None:
                continue

            def exact_gain(idx):
                if idx is None:
                    return 0.0
                e = ledger[idx]
                return e["exact"][2] if e["exact"] is not None else e["gain_ns"]

            replaced_exact = exact_gain(first) + exact_gain(second)
            replaced_ledger = ((ledger[first]["gain_ns"] if first is not None else 0.0)
                               + (ledger[second]["gain_ns"] if second is not None else 0.0))
            if decision[2] <= max(replaced_exact, replaced_ledger) + 1e-9:
                continue
            chain = (ci, decision)
            if first is not None:
                ledger[first]["chain"] = chain
            else:
                ledger.append({"producer": ai, "consumer": bi, "pairs": 1,
                               "gain_ns": min(a["reduce_tail_ns"], b["dequant_slack_ns"]),
                               "exact": None, "chain": chain, "superseded": False})
            if second is not None:
                ledger[second]["superseded"] = True
    return ledger


def served_exact_gain(e):
    if e["superseded"]:
        return 0.0
    if e["chain"] is not None:
        return e["chain"][1][2]
    return e["exact"][2] if e["exact"] is not None else e["gain_ns"]


def simulate_step_with(batch, kv_len, heads, hidden, ffn, kv, group, moe,
                       resolve, overlap_mode="auto", residency_mode="auto"):
    nodes = []
    for spec in step_nodes(batch, kv_len, heads, hidden, ffn, kv, group, moe):
        if spec[0] == "gemm":
            _, kind, problem, count = spec
            strategy, t = resolve(problem)
            node = simulate_gemm_node(problem, count, strategy, t)
            node["kind"] = kind
            nodes.append(node)
        else:
            _, kind, elems, ops, hbm, l2b = spec
            nodes.append(price_pass(elems, ops, hbm, l2b))
    sequential_ns = 0.0
    for n in nodes:
        sequential_ns += n["total_ns"] if isinstance(n, dict) else n
    price_exact = overlap_mode in ("exact", "auto")
    ledger = build_ledger_pairs(nodes, price_exact)
    gain = sum(float(e["pairs"]) * e["gain_ns"] for e in ledger)
    exact_gain = sum(float(e["pairs"]) * served_exact_gain(e) for e in ledger)
    residency = None
    if residency_mode == "auto":
        inputs = []
        extra_ns = 0.0
        for n in nodes:
            if isinstance(n, dict):
                inputs.append({"problem": n["problem"], "count": n["count"],
                               "unit_ns": n["unit_ns"], "trace": n["trace"]})
            else:
                extra_ns += n
        residency = plan_nodes(inputs, extra_ns, price_exact)
    rep = {
        "nodes": nodes,
        "sequential_ns": sequential_ns,
        "overlapped_ns": sequential_ns - gain,
        "exact_ns": sequential_ns - exact_gain,
        "residency": residency,
    }
    base = {
        "sequential": rep["sequential_ns"],
        "overlapped": rep["overlapped_ns"],
        "exact": rep["exact_ns"],
        "auto": min(rep["exact_ns"], rep["overlapped_ns"], rep["sequential_ns"]),
    }[overlap_mode]
    rep["served_ns"] = min(base, residency["resident_ns"]) if residency else base
    rep["mode_base_ns"] = base
    return rep


# --- coordinator/server.rs: continuous-batching serve mirror ---------------
#
# Mirror of `Server::serve_load` for the e2e_serve bench: fault-free,
# deadline-free runs over a warmed tune cache.  Token *values* never
# influence scheduling (the done condition depends only on counts and
# positions), so the decode engine itself is not mirrored — only the
# seeded arrival plan, the KV pager, the warmed-cache router pricing and
# the integer-microsecond event loop.

MASK64 = (1 << 64) - 1


def _rotl64(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    """xoshiro256** seeded via splitmix64 (util/prng.rs)."""

    def __init__(self, seed):
        sm = seed & MASK64
        s = []
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl64((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl64(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / float(1 << 53))

    def usize_range(self, lo, hi):
        return lo + self.next_u64() % (hi - lo + 1)

    def exponential(self, rate):
        return -math.log(max(self.f64(), 1e-300)) / rate


def poisson_plan(seed, mean_gap_us, count, max_seq):
    """Mirror of ArrivalPlan::poisson: list of (at_us, prompt_len,
    max_new_tokens), drawn in the exact Rust order."""
    rng = Rng(seed)
    rate = 1.0 / max(mean_gap_us, 1.0)
    at_us = 0
    arrivals = []
    for _ in range(count):
        at_us += max(int(math.ceil(rng.exponential(rate))), 1)
        prompt_len = rng.usize_range(2, max(max_seq // 4, 2))
        budget_cap = max(max(max_seq - prompt_len, 0) - 1, 1)
        new_lo = min(4, budget_cap)
        new_hi = max(min(max_seq // 2, budget_cap), new_lo)
        max_new = rng.usize_range(new_lo, new_hi)
        arrivals.append((at_us, prompt_len, max_new))
    return arrivals


# --- model/kv_cache.rs -----------------------------------------------------

DEFAULT_PAGE_BYTES = 2 << 20
HBM_CAPACITY_BYTES = 32 << 30  # MachineConfig::ascend910
HOST_LINK_BW = 64.0  # MachineConfig::ascend910 host_link_bw (bytes/ns)
SERVE_MAX_WAIT_US = 50_000  # batcher::DEFAULT_MAX_WAIT_US
DEFAULT_MAX_PREEMPTIONS = 2  # server::DEFAULT_MAX_PREEMPTIONS


def kv_bytes_per_token(layers, kv_width):
    return layers * 2 * kv_width * 2


class KvPager:
    """Mirror of model::kv_cache::KvPager: fixed-size pages, conservative
    worst-case reservation at admission, growth per decoded token."""

    def __init__(self, page_bytes, capacity_bytes):
        self.page_bytes = max(page_bytes, 1)
        self.capacity_pages = capacity_bytes // self.page_bytes
        self.allocated = 0
        self.reserved = 0
        self.peak = 0
        self.seqs = {}  # id -> [bytes_per_token, worst, pages, tokens]

    def pages_for(self, tokens, bytes_per_token):
        return -(-(tokens * bytes_per_token) // self.page_bytes)

    def try_admit(self, sid, prompt_tokens, max_new, bytes_per_token):
        worst = self.pages_for(prompt_tokens + max_new, bytes_per_token)
        if self.reserved + worst > self.capacity_pages:
            return False
        pages = self.pages_for(prompt_tokens, bytes_per_token)
        self.reserved += worst
        self.allocated += pages
        self.peak = max(self.peak, self.allocated)
        self.seqs[sid] = [bytes_per_token, worst, pages, prompt_tokens]
        return True

    def grow(self, sid):
        s = self.seqs[sid]
        s[3] += 1
        need = self.pages_for(s[3], s[0])
        if need > s[2]:
            self.allocated += need - s[2]
            s[2] = need
            self.peak = max(self.peak, self.allocated)

    def release(self, sid):
        s = self.seqs.pop(sid)
        self.reserved -= s[1]
        self.allocated -= s[2]
        return s[2]

    def preempt(self, sid):
        """Mirror of KvPager::preempt: drop pages AND reservation;
        returns (pages, bytes)."""
        s = self.seqs.pop(sid)
        self.reserved -= s[1]
        self.allocated -= s[2]
        return s[2], s[2] * self.page_bytes

    def try_resume(self, sid, resident_tokens, remaining_new, bytes_per_token):
        """Mirror of KvPager::try_resume (= try_admit at the resume
        footprint: resident + remaining == prompt + max_new)."""
        return self.try_admit(sid, resident_tokens, remaining_new, bytes_per_token)

    def idle(self):
        return not self.seqs and self.allocated == 0 and self.reserved == 0


# --- workload/prefill.rs ---------------------------------------------------

def prefill_nodes(m, kv_base, heads, hidden, ffn, kv, group, moe=None):
    """Mirror of PrefillStep::nodes: the decode graph with the attention
    passes sized by the exact causal context
    ctx = m*kv_base + m*(m+1)/2 and scores = heads*ctx."""
    h = hidden
    heads = max(heads, 1)
    head_dim = float(hidden) / float(heads)
    ctx = m * kv_base + m * (m + 1) // 2
    scores = heads * ctx
    norm = ("vector", "rmsnorm", m * h, 6.0, 0, 2 * m * h * 2)
    residual = ("vector", "residual", m * h, 1.0, 0, 3 * m * h * 2)
    nodes = [
        norm,
        ("gemm", "qkv", (m, h + 2 * kv, h, group), 1),
        ("vector", "attn_score", scores, 2.0 * head_dim,
         ctx * kv * 2, m * h * 2 + scores * 2),
        ("vector", "attn_softmax", scores, 8.0, 0, 2 * scores * 2),
        ("vector", "attn_av", scores, 2.0 * head_dim,
         ctx * kv * 2, scores * 2 + m * h * 2),
        ("gemm", "attn_out", (m, h, h, group), 1),
        residual,
        norm,
    ]
    if moe is None:
        nodes += [
            ("gemm", "up_gate", (m, 2 * ffn, h, group), 1),
            ("vector", "activation", m * ffn, 4.0, 0, 3 * m * ffn * 2),
            ("gemm", "down", (m, h, ffn, group), 1),
        ]
    else:
        experts, topk, ef = moe
        topk = max(topk, 1)
        active = moe_active(experts, topk, m)
        tokens = moe_tokens(experts, topk, m)
        routed = active * tokens
        nodes += [
            ("vector", "moe_route", m * experts, 2.0 * float(h) + 8.0,
             h * experts * 2, m * h * 2 + m * experts * 2),
            ("gemm", "moe_expert", (tokens, 2 * ef, h, group), active),
            ("vector", "activation", routed * ef, 4.0, 0, 3 * routed * ef * 2),
            ("gemm", "moe_expert", (tokens, h, ef, group), active),
        ]
    nodes.append(residual)
    return nodes


def prefill_vector_ns(m, kv_base, heads, hidden, ffn, kv, group, moe=None):
    """Mirror of coordinator::server::prefill_vector_ns."""
    total = 0.0
    for spec in prefill_nodes(m, kv_base, heads, hidden, ffn, kv, group, moe):
        if spec[0] == "vector":
            _, _, elems, ops, hbm, l2b = spec
            total += price_pass(elems, ops, hbm, l2b)
    return total


# --- coordinator/router.rs: warmed-cache pricing ---------------------------

def decode_gemm_nodes(m, hidden, ffn, group, moe=None):
    """Mirror of DecodeLayer::from_decode_config(cfg, m).gemm_nodes():
    the decode geometry sets kv = hidden; MoE (experts, topk, expert_ffn
    = cfg.ffn) replaces the dense FFN pair with the routed expert pair.
    Entries are (kind, problem, count)."""
    h = hidden
    kv = hidden
    nodes = [("qkv", (m, h + 2 * kv, h, group), 1),
             ("attn_out", (m, h, h, group), 1)]
    if moe is None:
        nodes += [("up_gate", (m, 2 * ffn, h, group), 1),
                  ("down", (m, h, ffn, group), 1)]
    else:
        experts, topk, ef = moe
        topk = max(topk, 1)
        active = moe_active(experts, topk, m)
        tokens = moe_tokens(experts, topk, m)
        nodes += [("moe_expert", (tokens, 2 * ef, h, group), active),
                  ("moe_expert", (tokens, h, ef, group), active)]
    return nodes


def decode_gemm_weight_bytes(m, hidden, ffn, group, moe=None):
    """Mirror of server::prefill_chunk_weight_bytes: packed-weight bytes
    one chunk of width m streams (count * n*k/2 over the issued GEMMs —
    active experts only on MoE layers)."""
    return sum(count * (p[1] * p[2] // 2)
               for _, p, count in decode_gemm_nodes(m, hidden, ffn, group, moe))


def swap_one_way_us(bytes_):
    """Mirror of server::swap_tick_us: virtual µs to move bytes across
    the host link one way."""
    if bytes_ == 0:
        return 0
    return max(int(math.ceil(bytes_ / HOST_LINK_BW / 1000.0)), 1)


def overlap_pair_list(gemms):
    """Mirror of DecodeLayer::overlap_pairs over a gemm-node list: the
    internal (self) pairs of multi-count nodes in node order, then the
    adjacent windows.  Entries are (producer, consumer, pairs)."""
    pairs = [(p, p, count - 1) for _, p, count in gemms if count > 1]
    pairs += [(a[1], b[1], 1) for a, b in zip(gemms, gemms[1:])]
    return pairs


class ServePlanner:
    """Mirror of the Router's warmed-cache pricing (LayerPlan at the
    `full` rung): layer ns from cached tuned totals, overlap gains from
    the pair cache, residency gain / pinned bytes from the layer-keyed
    residency cache (tune/mod.rs + tune/cache.rs).

    Cache keys alias by *padded* M (tune/cache.rs), and the layer key
    carries per-node counts — so warming order matters: the first
    problem of each padded class prices the entry.  `warm` must replay
    the bench's exact seeding order (m in 1..=chunk, then the batch)."""

    def __init__(self):
        self.tuner = Tuner()
        self.pair_cache = {}
        self.residency_cache = {}

    def _trace(self, p):
        s, t, _ = self.tuner.resolve(p)
        return schedule_with_reduce(p, s, t, "auto")

    def pair_gain(self, pp, cp):
        key = (self.tuner.key(pp), self.tuner.key(cp))
        if key not in self.pair_cache:
            _, _, pns = self.tuner.resolve(pp)
            _, _, cns = self.tuner.resolve(cp)
            d = pair_decision_with(self._trace(pp), self._trace(cp), pns + cns)
            self.pair_cache[key] = d[2] if d is not None else 0.0
        return self.pair_cache[key]

    def residency(self, gemms):
        key = tuple((kind, count) + self.tuner.key(p) for kind, p, count in gemms)
        if key not in self.residency_cache:
            inputs = []
            for _, p, count in gemms:
                _, _, unit_ns = self.tuner.resolve(p)
                inputs.append({"problem": p, "count": max(count, 1),
                               "unit_ns": unit_ns, "trace": self._trace(p)})
            plan = plan_nodes(inputs, 0.0, True)
            self.residency_cache[key] = (plan["gain_ns"], plan["pinned_bytes"])
        return self.residency_cache[key]

    def warm(self, gemms):
        """Mirror of the bench's tune-cache seeding for one layer graph."""
        for _, p, _ in gemms:
            self.tuner.resolve(p)
        for pp, cp, _ in overlap_pair_list(gemms):
            self.pair_gain(pp, cp)
        self.residency(gemms)

    def layer_plan(self, gemms):
        """(predicted_served_ns, residency_pinned_bytes) for a warmed
        cache: max(max(layer - overlap, 0) - residency_gain, 0)."""
        layer_ns = 0.0
        for _, p, count in gemms:
            _, _, unit_ns = self.tuner.resolve(p)
            layer_ns += unit_ns * float(count)
        overlap = sum(float(pairs) * self.pair_gain(pp, cp)
                      for pp, cp, pairs in overlap_pair_list(gemms))
        gain, pinned = self.residency(gemms)
        served = max(max(layer_ns - overlap, 0.0) - gain, 0.0)
        return served, pinned


# --- util/stats.rs ---------------------------------------------------------

def percentile(sorted_xs, q):
    """Mirror of util::stats::percentile (linear interpolation)."""
    n = len(sorted_xs)
    if n == 0:
        return 0.0
    if n == 1:
        return sorted_xs[0]
    pos = min(max(q, 0.0), 1.0) * float(n - 1)
    lo = int(math.floor(pos))
    hi = int(math.ceil(pos))
    frac = pos - float(lo)
    return sorted_xs[lo] * (1.0 - frac) + sorted_xs[hi] * frac


# --- coordinator/server.rs: the serve event loop ---------------------------

def serve_load(cfg, planner, arrivals, batch, chunk, queue_cap,
               preempt="off", max_preemptions=DEFAULT_MAX_PREEMPTIONS,
               capacity_bytes=HBM_CAPACITY_BYTES,
               max_wait_us=SERVE_MAX_WAIT_US):
    """Mirror of Server::serve_load on a warmed cache with no fault plan
    and no deadlines: one dict of the counters the e2e_serve bench
    reports.  cfg keys: hidden, layers, heads, ffn, max_seq, group, moe
    (None or (experts, topk, expert_ffn)).  preempt mirrors
    PreemptPolicy (off | recompute | swap | auto); under KV pressure the
    admission path evicts LRU victims (least-recent tick, then shortest
    generation, then lowest slot), parking them on a resume queue that
    seats ahead of fresh arrivals."""
    hidden, layers = cfg["hidden"], cfg["layers"]
    heads = max(cfg["heads"], 1)
    ffn, max_seq, group = cfg["ffn"], cfg["max_seq"], cfg["group"]
    moe = cfg.get("moe")
    chunk = max(chunk, 1)
    queue_cap = max(queue_cap, 1)
    bpt = kv_bytes_per_token(max(layers, 1), max(hidden, 1))
    pager = KvPager(DEFAULT_PAGE_BYTES, capacity_bytes)
    served_ns, pinned = planner.layer_plan(
        decode_gemm_nodes(max(batch, 1), hidden, ffn, group, moe))
    decode_step_us = max(int(math.ceil(served_ns / 1000.0)), 1)

    slots = [None] * max(batch, 1)
    queue = []
    parked = []  # (slot, mode, bytes) — mode in ("recompute", "swap")
    clock = 0
    next_arrival = 0
    tick_seq = 0
    # Pinned bytes displaced by prefill since the last decode tick —
    # prices the churn-fraction repin (repin_decayed_ns).
    evicted = 0
    met = {"admitted": 0, "completed": 0, "shed": 0,
           "shed_queue_full": 0, "shed_kv_capacity": 0,
           "tokens_generated": 0, "ttft_us": [], "gap_us": [],
           "prefill_steps": 0, "prefill_tokens": 0, "decode_steps": 0,
           "repins": 0, "repin_ns_sum": 0.0,
           "preempted": 0, "resumed": 0, "swap_bytes": 0, "swap_us_sum": 0,
           "recompute_ticks": 0, "recompute_us_sum": 0}
    last_was_prefill = False

    def remaining(s):
        return s["target"] - s["prefilled"]

    def price_recompute(resident_tokens):
        # Mirror of Server::price_recompute_us: the exact chunked
        # re-prefill bill of the resident prefix.
        target = max(resident_tokens - 1, 0)
        done = 0
        total = 0
        while done < target:
            m = min(target - done, chunk)
            gemm_ns, _ = planner.layer_plan(
                decode_gemm_nodes(m, hidden, ffn, group, moe))
            vec_ns = prefill_vector_ns(m, done, heads, hidden,
                                       ffn, hidden, group, moe)
            total += max(int(math.ceil((gemm_ns + vec_ns) / 1000.0)), 1)
            done += m
        return total

    def preempt_victim():
        # Mirror of Server::preempt_victim: LRU pick over decode-phase
        # residents, free pages and reservation, choose the recovery
        # path, park.
        nonlocal clock
        best = None
        for i, s in enumerate(slots):
            if (s is None or s["cycles"] >= max_preemptions
                    or remaining(s) > 0):
                continue
            if best is None or ((s["last_tick"], s["generated"])
                                < (slots[best]["last_tick"],
                                   slots[best]["generated"])):
                best = i
        if best is None:
            return False
        s = slots[best]
        slots[best] = None
        _pages, bytes_ = pager.preempt(s["id"])
        s["cycles"] += 1
        swap1 = swap_one_way_us(bytes_)
        if preempt == "recompute":
            mode = "recompute"
        elif preempt == "swap":
            mode = "swap"
        else:  # auto: swap pays the link twice (out now, in at resume)
            resident = s["prompt_len"] + s["generated"]
            mode = ("swap" if swap1 * 2 <= price_recompute(resident)
                    else "recompute")
        met["preempted"] += 1
        if mode == "recompute":
            s["recovering"] = True
            s["target"] = max(s["prompt_len"] + s["generated"] - 1, 0)
            s["prefilled"] = 0
            s["position"] = 0
        else:
            clock += swap1
            met["swap_bytes"] += bytes_
            met["swap_us_sum"] += swap1
        parked.append((s, mode, bytes_))
        return True

    while True:
        # Admit every arrival at or before the clock (record_admitted,
        # queue-cap shed, conservative KV reservation, FIFO enqueue).
        # Under KV pressure a non-off policy preempts LRU victims until
        # the reservation fits — unless the request could never fit even
        # on an empty pager, or every resident exhausted its budget.
        while next_arrival < len(arrivals) and arrivals[next_arrival][0] <= clock:
            at_us, prompt_len, max_new = arrivals[next_arrival]
            rid = next_arrival
            next_arrival += 1
            met["admitted"] += 1
            if len(queue) >= queue_cap:
                met["shed"] += 1
                met["shed_queue_full"] += 1
                continue
            if not pager.try_admit(rid, prompt_len, max_new, bpt):
                worst = pager.pages_for(prompt_len + max_new, bpt)
                admitted_kv = False
                if preempt != "off" and worst <= pager.capacity_pages:
                    while preempt_victim():
                        if pager.try_admit(rid, prompt_len, max_new, bpt):
                            admitted_kv = True
                            break
                if not admitted_kv:
                    met["shed"] += 1
                    met["shed_kv_capacity"] += 1
                    continue
            queue.append({"id": rid, "prompt_len": prompt_len,
                          "max_new": max_new, "enqueued": at_us,
                          "prefilled": 0, "target": prompt_len - 1,
                          "position": 0, "generated": 0,
                          "last_tick": tick_seq, "cycles": 0,
                          "recovering": False})
        # (Deadline expiry paths are no-ops: the bench sets no deadline.)
        # Anti-starvation: every slot busy and the queue head out-waited
        # the batching window — preempt one victim and seat the head
        # (already holding its KV reservation) directly into the freed
        # slot, ahead of the resume queue's refill priority.
        if (preempt != "off"
                and all(s is not None for s in slots) and queue
                and clock - queue[0]["enqueued"] >= max_wait_us
                and preempt_victim()):
            head = queue.pop(0)
            head["last_tick"] = tick_seq
            slots[next(i for i, s in enumerate(slots) if s is None)] = head
        # Refill free slots: resume queue first (first-fit FIFO), then
        # fresh arrivals.
        for i in range(len(slots)):
            if slots[i] is not None:
                continue
            seated = False
            for pi, (ps, mode, bytes_) in enumerate(parked):
                resident = ps["prompt_len"] + ps["generated"]
                rem = max(ps["max_new"] - ps["generated"], 0)
                if pager.try_resume(ps["id"], resident, rem, bpt):
                    parked.pop(pi)
                    if mode == "swap":
                        swap_in = swap_one_way_us(bytes_)
                        clock += swap_in
                        met["swap_bytes"] += bytes_
                        met["swap_us_sum"] += swap_in
                    met["resumed"] += 1
                    ps["last_tick"] = tick_seq
                    slots[i] = ps
                    seated = True
                    break
            if seated:
                continue
            if queue:
                slots[i] = queue.pop(0)
                slots[i]["last_tick"] = tick_seq
            else:
                break
        if all(s is None for s in slots):
            assert not parked, "idle slots must have drained the resume queue"
            if next_arrival < len(arrivals):
                clock = max(clock, arrivals[next_arrival][0])
                continue
            break
        # One tick: prefill and decode strictly alternate while both wait.
        has_prefill = any(s is not None and remaining(s) > 0 for s in slots)
        has_decode = any(s is not None and remaining(s) == 0 for s in slots)
        if has_prefill and (not has_decode or not last_was_prefill):
            i = next(i for i, s in enumerate(slots)
                     if s is not None and remaining(s) > 0)
            s = slots[i]
            m = min(remaining(s), chunk)
            gemm_ns, _ = planner.layer_plan(
                decode_gemm_nodes(m, hidden, ffn, group, moe))
            vec_ns = prefill_vector_ns(m, s["position"], heads, hidden,
                                       ffn, hidden, group, moe)
            prefill_tick_us = max(int(math.ceil((gemm_ns + vec_ns) / 1000.0)), 1)
            clock += prefill_tick_us
            tick_seq += 1
            # The chunk's streamed weights displace pinned decode
            # residents, capped at the pinned set.
            evicted = min(
                evicted + decode_gemm_weight_bytes(m, hidden, ffn, group, moe),
                pinned)
            s["prefilled"] += m
            s["position"] += m
            s["last_tick"] = tick_seq
            met["prefill_steps"] += 1
            met["prefill_tokens"] += m
            if s["recovering"]:
                met["recompute_ticks"] += 1
                met["recompute_us_sum"] += prefill_tick_us
                if remaining(s) == 0:
                    s["recovering"] = False
            last_was_prefill = True
        else:
            active = [i for i, s in enumerate(slots)
                      if s is not None and remaining(s) == 0]
            tick_start = clock
            tick_seq += 1
            tick_us = decode_step_us
            if evicted > 0 and pinned > 0:
                # Churn-fraction repin (repin_decayed_ns): the surcharge
                # scales with what the burst actually displaced.
                repin_ns = float(min(evicted, pinned)) / HBM_BW
                if repin_ns > 0.0:
                    met["repins"] += 1
                    met["repin_ns_sum"] += repin_ns
                    tick_us += max(int(math.ceil(repin_ns / 1000.0)), 1)
            evicted = 0
            clock += tick_us
            met["decode_steps"] += 1
            emitted = 0
            for i in active:
                s = slots[i]
                s["last_tick"] = tick_seq
                s["position"] += 1
                pager.grow(s["id"])
                emitted += 1
                if s["generated"] == 0:
                    met["ttft_us"].append(float(clock - s["enqueued"]))
                s["generated"] += 1
                if s["generated"] >= s["max_new"] or s["position"] + 1 >= max_seq:
                    pager.release(s["id"])
                    met["completed"] += 1
                    met["tokens_generated"] += s["generated"]
                    slots[i] = None
            met["gap_us"].extend([float(clock - tick_start)] * emitted)
            last_was_prefill = False

    assert pager.idle(), "kv pager must drain"
    assert met["preempted"] == met["resumed"], "preemption conservation"
    met["horizon_us"] = clock
    met["kv_peak_pages"] = pager.peak
    met["kv_capacity_pages"] = pager.capacity_pages
    return met
