//! Bench: regenerate the paper's **Figure 2** — execution time of the
//! INT4xFP16 kernel across N x K configurations and batch sizes, Split-K
//! vs Data-Parallel (simulated Ascend 910).
//!
//! Expected shape (paper §4.1): Split-K wins when K >> N with speedups in
//! ~[1.0, 1.8]; parity when N is large; execution time flat in M until the
//! cube tile is filled.  Run with `cargo bench --bench fig2_splitk_vs_dp`.

use ascend_w4a16::analysis::report;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::bench::{section, Bench};

fn main() {
    let machine = MachineConfig::ascend910();

    section("Figure 2 sweep (simulated)");
    let cells = report::fig2_sweep(&machine).expect("sweep");
    print!("{}", report::render_fig2(&cells));

    // Persist the JSON series for EXPERIMENTS.md.
    let out = "target/fig2.json";
    std::fs::write(out, report::fig2_json(&cells).to_string()).expect("write json");
    println!("\nwrote {out}");

    section("harness wallclock (simulator throughput)");
    let r = Bench::new("fig2 full sweep (84 cells x 2 strategies)")
        .warmup(1)
        .iters(5)
        .run(|| {
            std::hint::black_box(report::fig2_sweep(&machine).unwrap());
        });
    println!("{}", r.render_row());
}
