//! Simulator performance bench (the L3 hot path of the analysis tooling).
//!
//! Tracks trace-construction and pricing throughput so the perf pass
//! (EXPERIMENTS.md §Perf) has a stable measurement target.
//! Run with `cargo bench --bench sim_perf`.

use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::bench::{section, Bench};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};

fn main() {
    let machine = MachineConfig::ascend910();
    let sim = Simulator::new(machine.clone());

    section("schedule construction");
    for (n, k) in [(2048usize, 7168usize), (12288, 5120)] {
        let p = GemmProblem::new(8, n, k);
        let r = Bench::new(format!("schedule splitk n={n} k={k}"))
            .warmup(3)
            .iters(30)
            .run(|| {
                std::hint::black_box(
                    kernels::schedule(&machine, &p, Strategy::SplitK).unwrap(),
                );
            });
        println!("{}", r.render_row());
    }

    section("trace pricing (Simulator::run)");
    for (n, k) in [(2048usize, 7168usize), (12288, 5120)] {
        let p = GemmProblem::new(8, n, k);
        let trace = kernels::schedule(&machine, &p, Strategy::SplitK).unwrap();
        let r = Bench::new(format!("simulate splitk n={n} k={k} ({} steps)",
                trace.phases.iter().map(|p| p.total_steps()).sum::<usize>()))
            .warmup(3)
            .iters(30)
            .run(|| {
                std::hint::black_box(sim.run(&trace).unwrap());
            });
        println!("{}", r.render_row());
    }

    section("full figure sweeps");
    let r = Bench::new("fig2+fig3 sweeps back to back")
        .warmup(1)
        .iters(5)
        .run(|| {
            use ascend_w4a16::analysis::report;
            std::hint::black_box(report::fig2_sweep(&machine).unwrap());
            std::hint::black_box(report::fig3_sweep(&machine).unwrap());
        });
    println!("{}", r.render_row());
}
