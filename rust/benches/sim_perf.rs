//! Simulator performance bench (the L3 hot path of the analysis tooling).
//!
//! Tracks trace-construction and pricing throughput so the perf pass
//! (EXPERIMENTS.md §Perf) has a stable measurement target, plus the two
//! parallelized hot loops of the analysis stack:
//!
//! * tune-cache seeding — a serial `Tuner::resolve` sweep vs the pooled
//!   `Tuner::resolve_many` (cache misses searched on the thread pool);
//! * residency prefix re-pricing — the greedy planner's serial
//!   per-prefix `price_pins` loop (`plan_nodes_serial`) vs the pooled
//!   price-only loop (`plan_nodes`) on the deepseek-moe decode step
//!   graph.
//!
//! Both pairs are asserted bit-identical before their wall clocks are
//! reported, and the timings land in `target/BENCH_sim_perf.json`.
//! Wall-clock cells (`*wall*`) measure the host machine and never gate
//! in bench-diff.
//!
//! Run with `cargo bench --bench sim_perf`.

use std::time::Instant;

use ascend_w4a16::analysis::residency::{plan_nodes, plan_nodes_serial, PlanNodeInput, ResidencyPlan};
use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::bench::{section, Bench};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::model::llm::{layer_geometry, moe_geometry};
use ascend_w4a16::tune::{self, Tuner};
use ascend_w4a16::util::json::Json;
use ascend_w4a16::util::pool;
use ascend_w4a16::workload::DecodeLayer;

const MODEL: &str = "deepseek-moe";

fn assert_plans_bit_identical(serial: &ResidencyPlan, pooled: &ResidencyPlan) {
    assert_eq!(
        serial.resident_ns.to_bits(),
        pooled.resident_ns.to_bits(),
        "pooled planner must price bit-identically to the serial reference"
    );
    assert_eq!(serial.baseline_ns.to_bits(), pooled.baseline_ns.to_bits());
    assert_eq!(serial.pins, pooled.pins);
    assert_eq!(serial.pinned_bytes, pooled.pinned_bytes);
    assert_eq!(serial.budget_bytes, pooled.budget_bytes);
}

/// The deepseek-moe decode-step GEMM sub-chain at batch 8 as residency
/// planner inputs (fused schedules — the planner's main beneficiary).
fn prefix_inputs(machine: &MachineConfig) -> Vec<PlanNodeInput> {
    let sim = Simulator::new(machine.clone());
    let geom = layer_geometry(MODEL).expect("paper model");
    let layer = DecodeLayer::new(geom, 8).with_moe(moe_geometry(MODEL).expect("moe preset"));
    layer
        .gemm_nodes()
        .into_iter()
        .filter(|n| n.problem.validate().is_ok())
        .map(|n| {
            let trace = kernels::schedule(machine, &n.problem, Strategy::Fused).expect("schedule");
            let unit_ns = sim.run(&trace).expect("price").total_ns;
            PlanNodeInput { kind: n.kind, problem: n.problem, count: n.count, unit_ns, trace }
        })
        .collect()
}

/// Unique decode-layer GEMM problems of the deepseek-moe graph across the
/// bench batch sweep (padded-M aliases deduplicated like `repro tune`).
fn tune_problems(machine: &MachineConfig) -> Vec<GemmProblem> {
    let geom = layer_geometry(MODEL).expect("paper model");
    let moe = moe_geometry(MODEL).expect("moe preset");
    let mut seen = std::collections::BTreeSet::new();
    let mut problems = Vec::new();
    for batch in [1usize, 8, 64] {
        for node in DecodeLayer::new(geom, batch).with_moe(moe).gemm_nodes() {
            if node.problem.validate().is_ok() && seen.insert(tune::shape_key(machine, &node.problem))
            {
                problems.push(node.problem);
            }
        }
    }
    problems
}

fn main() {
    let machine = MachineConfig::ascend910();
    let sim = Simulator::new(machine.clone());
    let mut cells = Vec::new();

    section("schedule construction");
    for (n, k) in [(2048usize, 7168usize), (12288, 5120)] {
        let p = GemmProblem::new(8, n, k);
        let r = Bench::new(format!("schedule splitk n={n} k={k}"))
            .warmup(3)
            .iters(30)
            .run(|| {
                std::hint::black_box(
                    kernels::schedule(&machine, &p, Strategy::SplitK).unwrap(),
                );
            });
        println!("{}", r.render_row());
    }

    section("trace pricing (Simulator::run)");
    for (n, k) in [(2048usize, 7168usize), (12288, 5120)] {
        let p = GemmProblem::new(8, n, k);
        let trace = kernels::schedule(&machine, &p, Strategy::SplitK).unwrap();
        let r = Bench::new(format!("simulate splitk n={n} k={k} ({} steps)",
                trace.phases.iter().map(|p| p.total_steps()).sum::<usize>()))
            .warmup(3)
            .iters(30)
            .run(|| {
                std::hint::black_box(sim.run(&trace).unwrap());
            });
        println!("{}", r.render_row());
    }

    section("full figure sweeps");
    let r = Bench::new("fig2+fig3 sweeps back to back")
        .warmup(1)
        .iters(5)
        .run(|| {
            use ascend_w4a16::analysis::report;
            std::hint::black_box(report::fig2_sweep(&machine).unwrap());
            std::hint::black_box(report::fig3_sweep(&machine).unwrap());
        });
    println!("{}", r.render_row());

    // ---- tune-cache seeding: serial resolve loop vs pooled resolve_many.
    // Both start from a cold in-memory cache, so every problem is a live
    // tiling search; the pooled leg farms the misses out to the thread
    // pool and must return exactly what the serial loop resolved.
    section(&format!("tune-cache seeding — serial vs pooled ({MODEL} graph)"));
    let problems = tune_problems(&machine);
    let workers = pool::worker_count(problems.len());

    let mut serial_tuner = Tuner::new(machine.clone());
    let start = Instant::now();
    let serial_entries: Vec<_> = problems
        .iter()
        .map(|p| serial_tuner.resolve(p).expect("serial resolve"))
        .collect();
    let tune_serial_us = start.elapsed().as_secs_f64() * 1e6;

    let mut pooled_tuner = Tuner::new(machine.clone());
    let start = Instant::now();
    let pooled_entries = pooled_tuner.resolve_many(&problems).expect("pooled resolve");
    let tune_pooled_us = start.elapsed().as_secs_f64() * 1e6;

    assert_eq!(serial_entries.len(), pooled_entries.len());
    for (s, p) in serial_entries.iter().zip(&pooled_entries) {
        assert_eq!(s.strategy, p.strategy, "pooled tuning changed a winner");
        assert_eq!(s.total_ns.to_bits(), p.total_ns.to_bits());
    }
    let tune_speedup = tune_serial_us / tune_pooled_us;
    println!(
        "{} shapes: serial {:.0} us, pooled {:.0} us ({workers} workers) -> {tune_speedup:.2}x",
        problems.len(),
        tune_serial_us,
        tune_pooled_us,
    );
    cells.push(Json::obj(vec![
        ("leg", Json::str("tune_seed")),
        ("model", Json::str(MODEL)),
        ("problems", Json::num(problems.len() as f64)),
        ("workers", Json::num(workers as f64)),
        ("tune_serial_wall_us", Json::num(tune_serial_us)),
        ("tune_pooled_wall_us", Json::num(tune_pooled_us)),
        ("tune_speedup", Json::num(tune_speedup)),
    ]));

    // ---- residency prefix re-pricing: the serial reference re-runs the
    // full report-building `price_pins` per greedy prefix; the pooled
    // planner prices every prefix through the hoisted price-only path.
    // Identical greedy fill, identical accumulation order — the plans
    // must match to the bit before the wall clocks mean anything.
    section(&format!("residency prefix re-pricing — serial vs pooled ({MODEL} b=8)"));
    let inputs = prefix_inputs(&machine);
    for exact in [false, true] {
        let serial_plan = plan_nodes_serial(&machine, &inputs, 0.0, exact).expect("serial plan");
        let pooled_plan = plan_nodes(&machine, &inputs, 0.0, exact).expect("pooled plan");
        assert_plans_bit_identical(&serial_plan, &pooled_plan);

        let time = |f: &dyn Fn() -> ResidencyPlan| -> f64 {
            std::hint::black_box(f()); // warmup
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let start = Instant::now();
                std::hint::black_box(f());
                best = best.min(start.elapsed().as_secs_f64() * 1e6);
            }
            best
        };
        let prefix_serial_us =
            time(&|| plan_nodes_serial(&machine, &inputs, 0.0, exact).expect("serial plan"));
        let prefix_pooled_us =
            time(&|| plan_nodes(&machine, &inputs, 0.0, exact).expect("pooled plan"));
        let prefix_speedup = prefix_serial_us / prefix_pooled_us;
        let workers = pool::worker_count(serial_plan.pins.len() + 1);
        println!(
            "exact={exact:<5} {} pins: serial {:.0} us, pooled {:.0} us ({workers} workers) \
             -> {prefix_speedup:.2}x",
            serial_plan.pins.len(),
            prefix_serial_us,
            prefix_pooled_us,
        );
        cells.push(Json::obj(vec![
            ("leg", Json::str("residency_prefix")),
            ("model", Json::str(MODEL)),
            ("batch", Json::num(8.0)),
            ("exact", Json::Bool(exact)),
            ("pins", Json::num(serial_plan.pins.len() as f64)),
            ("workers", Json::num(workers as f64)),
            ("prefix_serial_wall_us", Json::num(prefix_serial_us)),
            ("prefix_pooled_wall_us", Json::num(prefix_pooled_us)),
            ("prefix_speedup", Json::num(prefix_speedup)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("sim_perf")),
        ("cells", Json::arr(cells)),
    ]);
    std::fs::create_dir_all("target").expect("target dir");
    let out = "target/BENCH_sim_perf.json";
    std::fs::write(out, doc.to_string()).expect("write json");
    println!("\nwrote {out}");
}
