//! Ablation B — split-factor sweep: how does S affect Algorithm 1?
//!
//! More splits raise cube occupancy (helping when N/bn tiles < cores) but
//! add FP32 partial traffic and reduce work.  The auto-tiler's chosen S
//! should sit at or near each curve's minimum.
//! Run with `cargo bench --bench ablation_split_factor`.

use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::bench::section;
use ascend_w4a16::kernels::{splitk, tiling, GemmProblem};

fn main() {
    let machine = MachineConfig::ascend910();
    let sim = Simulator::new(machine.clone());
    const M: usize = 8;

    for (n, k) in [(512usize, 16384usize), (1024, 7680), (2048, 7168), (7168, 2048)] {
        section(&format!("split-factor sweep at N={n}, K={k}, M={M} (simulated µs)"));
        let p = GemmProblem::new(M, n, k);
        let auto = tiling::select_splitk(&machine, &p).expect("tiling");
        println!("auto-selected S = {}", auto.splits);
        println!("{:>4} {:>10} {:>10} {:>8}", "S", "time_us", "partials", "note");
        let mut best: Option<(usize, f64)> = None;
        for s in [1usize, 2, 4, 8, 16] {
            if k % s != 0 || (k / s) % p.group != 0 {
                println!("{s:>4} {:>10} {:>10} (K/S not group-aligned)", "-", "-");
                continue;
            }
            let t = tiling::Tiling { splits: s, ..auto };
            if t.validate(&machine, &p).is_err() {
                continue;
            }
            let trace = splitk::schedule(&machine, &p, &t).expect("schedule");
            let r = sim.run(&trace).expect("sim");
            let us = r.total_ns / 1e3;
            if best.map(|(_, b)| us < b).unwrap_or(true) {
                best = Some((s, us));
            }
            println!(
                "{s:>4} {us:>10.2} {:>10} {}",
                trace.partial_bytes / 1024,
                if s == auto.splits { "<- auto" } else { "" }
            );
        }
        if let Some((s_best, _)) = best {
            println!(
                "best S = {s_best}; auto-tiler picked {} ({})",
                auto.splits,
                if s_best == auto.splits { "optimal" } else { "within model noise" }
            );
        }
    }
}
