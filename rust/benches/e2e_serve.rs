//! Continuous-batching serve bench (DESIGN.md §15): offered-load sweeps
//! over two paper models — the dense llama32 trunk and the deepseek-moe
//! decoding scenario — on the virtual clock, with chunked prefill
//! interleaved against in-flight decode, KV-cache paging and the warmed
//! tune cache pricing every tick.
//!
//! Each cell submits a seeded Poisson arrival plan at one mean gap and
//! reports the SLO surface: TTFT and per-token-gap p50/p99 (virtual µs),
//! goodput (completed-output tokens per virtual second) against the
//! offered rate, the typed shed breakdown, and the KV-pager high-water
//! mark.  At overload the goodput must plateau while `queue_full` sheds
//! grow — the admission-control acceptance of the serve loop.
//!
//! Everything is deterministic (seeded arrivals, warmed cache, no fault
//! plan), so `target/BENCH_serve.json` is bit-reproducible and gated
//! against the mirror-generated `benches/baselines/BENCH_serve.json`.
//!
//! Run with `cargo bench --bench e2e_serve`.

use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::bench::section;
use ascend_w4a16::coordinator::{
    BatchPolicy, Batcher, MetricsSnapshot, PreemptPolicy, Router, ServeOptions, ServeReport,
    Server,
};
use ascend_w4a16::runtime::artifacts::DecodeConfig;
use ascend_w4a16::runtime::{Manifest, Runtime};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::util::json::Json;
use ascend_w4a16::workload::{ArrivalPlan, DecodeLayer};

/// Engine batch size (slot count) — one compiled decode artifact.
const BATCH: usize = 8;
/// Prompt tokens one prefill tick ingests.
const CHUNK: usize = 32;
/// Admission-queue bound: small enough that overload sheds visibly.
const QUEUE_CAP: usize = 12;
/// Requests per cell.
const REQUESTS: usize = 48;
/// Arrival-plan seed (shared across cells; the gap scales the load).
const SEED: u64 = 11;
/// Mean arrival gaps (µs), spanning under- to over-capacity.
const MEAN_GAP_US: [f64; 4] = [20_000.0, 2_000.0, 200.0, 20.0];
/// Deep-overload arrival gap for the armed preemption leg (µs).
const PREEMPT_GAP_US: f64 = 50.0;

/// Per-model armed preemption leg (DESIGN.md §18): a KV capacity and
/// anti-starvation window where deep overload separates `auto` from
/// `off` on both goodput and p99 TTFT, while at the light gap the two
/// policies are bit-identical (preemption never arms).  Mirrors
/// `PREEMPT_LEG` in `baselines/generate_baselines.py`.
struct PreemptLeg {
    capacity_bytes: u64,
    max_wait_us: u64,
    light_gap_us: f64,
}

fn preempt_leg(spec: &ModelSpec) -> PreemptLeg {
    if spec.cfg.moe_experts > 0 {
        PreemptLeg {
            capacity_bytes: 192 << 20,
            max_wait_us: 50_000,
            light_gap_us: 100_000.0,
        }
    } else {
        PreemptLeg {
            capacity_bytes: 300 << 20,
            max_wait_us: 6_000,
            light_gap_us: 20_000.0,
        }
    }
}

struct ModelSpec {
    name: &'static str,
    cfg: DecodeConfig,
}

/// The two serve models: the dense llama32 trunk geometry and the
/// deepseek-moe expert geometry (256 routed experts, top-8), both at a
/// bench-sized `max_seq` so prompts span several prefill chunks.
fn models() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "llama32",
            cfg: DecodeConfig {
                vocab: 4096,
                hidden: 2048,
                layers: 16,
                heads: 16,
                ffn: 8192,
                max_seq: 256,
                group: 128,
                params: 0,
                moe_experts: 0,
                moe_topk: 0,
            },
        },
        ModelSpec {
            name: "deepseek-moe",
            cfg: DecodeConfig {
                vocab: 4096,
                hidden: 7168,
                layers: 4,
                heads: 56,
                ffn: 2048,
                max_seq: 256,
                group: 128,
                params: 0,
                moe_experts: 256,
                moe_topk: 8,
            },
        },
    ]
}

/// Config-only decode manifest for one model at the bench batch size —
/// the router builds a synthetic engine, so no artifacts are needed.
fn manifest_json(spec: &ModelSpec) -> String {
    let c = &spec.cfg;
    format!(
        r#"{{
  "group": {group},
  "batch_sizes": [{batch}],
  "paper_shapes": [],
  "artifacts": [
    {{
      "name": "decode_{name}_b{batch}",
      "kind": "decode",
      "path": "decode_{name}_b{batch}.hlo.txt",
      "model": "{name}",
      "batch": {batch},
      "config": {{"vocab": {vocab}, "hidden": {hidden}, "layers": {layers},
                 "heads": {heads}, "ffn": {ffn}, "max_seq": {max_seq},
                 "group": {group}, "params": 0,
                 "moe_experts": {experts}, "moe_topk": {topk}}},
      "inputs": [],
      "outputs": []
    }}
  ]
}}"#,
        name = spec.name,
        batch = BATCH,
        vocab = c.vocab,
        hidden = c.hidden,
        layers = c.layers,
        heads = c.heads,
        ffn = c.ffn,
        max_seq = c.max_seq,
        group = c.group,
        experts = c.moe_experts,
        topk = c.moe_topk,
    )
}

/// Write the manifest plus a tune cache warmed for the decode batch and
/// every prefill chunk size the serve loop can route (1..=CHUNK; padded-M
/// aliasing dedups the searches), so every tick prices cache-only at the
/// `full` rung — exactly what the python mirror replays.
fn serve_dir(machine: &MachineConfig, spec: &ModelSpec) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("w4a16-serve-bench-{}-{}", spec.name, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json(spec)).unwrap();
    let mut tuner = Tuner::new(machine.clone());
    let mut ms: Vec<usize> = (1..=CHUNK).collect();
    ms.push(BATCH);
    for m in ms {
        let layer = DecodeLayer::from_decode_config(&spec.cfg, m);
        for node in layer.gemm_nodes() {
            tuner.resolve(&node.problem).unwrap();
        }
        for pair in layer.overlap_pairs() {
            tuner.resolve_overlap(&pair.producer, &pair.consumer).unwrap();
        }
        tuner.resolve_residency(&layer).unwrap();
    }
    tuner.save_to(dir.join("tune_cache.json")).unwrap();
    dir
}

fn bench_model(rt: &Runtime, machine: &MachineConfig, spec: &ModelSpec, cells: &mut Vec<Json>) {
    section(&format!(
        "serve load — {}{} (b={BATCH}, chunk={CHUNK}, queue_cap={QUEUE_CAP}, \
         {REQUESTS} requests/cell)",
        spec.name,
        if spec.cfg.moe_experts > 0 { " [MoE]" } else { "" },
    ));
    let dir = serve_dir(machine, spec);
    for mean_gap_us in MEAN_GAP_US {
        let plan = ArrivalPlan::poisson(SEED, mean_gap_us, REQUESTS, spec.cfg.max_seq);
        let offered_tok_per_s =
            plan.offered_tokens() as f64 / (plan.horizon_us().max(1) as f64 / 1e6);
        let mf = Manifest::load(&dir).unwrap();
        let router = Router::new(rt, mf, spec.name).unwrap();
        let policy = BatchPolicy::new(router.batch_sizes()).unwrap();
        let mut server = Server::new(router, Batcher::new(policy));
        let opts = ServeOptions::new(BATCH, CHUNK).with_queue_cap(QUEUE_CAP);
        let report = server.serve_load(&plan, &opts).expect("serve_load");
        assert!(report.kv_idle, "kv pager must drain");
        let snap = server.metrics.snapshot();
        assert!(snap.outcomes_accounted(), "conservation violated: {snap:?}");
        assert!(snap.sheds_accounted(), "typed sheds must close: {snap:?}");
        let goodput = snap.goodput_tokens_per_s(report.horizon_us);
        let shed_queue_full = snap.shed_reasons.get("queue_full").copied().unwrap_or(0);
        let shed_kv = snap.shed_reasons.get("kv_capacity").copied().unwrap_or(0);
        println!(
            "gap={mean_gap_us:>8.0} us  offered {offered_tok_per_s:>9.0} tok/s  \
             goodput {goodput:>9.0} tok/s  ttft p50 {:>8.0} p99 {:>8.0} us  \
             gap p50 {:>6.0} p99 {:>6.0} us  done {}  shed {}  kv peak {} pg",
            snap.serve_ttft_us.p50,
            snap.serve_ttft_us.p99,
            snap.serve_token_gap_us.p50,
            snap.serve_token_gap_us.p99,
            snap.requests_completed,
            snap.requests_shed,
            report.kv_peak_pages,
        );
        cells.push(Json::obj(vec![
            ("model", Json::str(spec.name)),
            ("moe", Json::Bool(spec.cfg.moe_experts > 0)),
            ("mean_gap_us", Json::num(mean_gap_us)),
            ("offered_tokens", Json::num(plan.offered_tokens() as f64)),
            ("offered_tok_per_s", Json::num(offered_tok_per_s)),
            ("goodput_tok_per_s", Json::num(goodput)),
            ("horizon_us", Json::num(report.horizon_us as f64)),
            ("admitted", Json::num(snap.requests_admitted as f64)),
            ("completed", Json::num(snap.requests_completed as f64)),
            ("shed", Json::num(snap.requests_shed as f64)),
            ("shed_queue_full", Json::num(shed_queue_full as f64)),
            ("shed_kv_capacity", Json::num(shed_kv as f64)),
            ("expired", Json::num(snap.requests_expired as f64)),
            ("failed", Json::num(snap.requests_failed as f64)),
            ("tokens_generated", Json::num(snap.tokens_generated as f64)),
            ("ttft_p50_us", Json::num(snap.serve_ttft_us.p50)),
            ("ttft_p99_us", Json::num(snap.serve_ttft_us.p99)),
            ("tok_gap_p50_us", Json::num(snap.serve_token_gap_us.p50)),
            ("tok_gap_p99_us", Json::num(snap.serve_token_gap_us.p99)),
            ("prefill_steps", Json::num(snap.prefill_steps as f64)),
            ("prefill_tokens", Json::num(snap.prefill_tokens as f64)),
            ("decode_steps", Json::num(snap.decode_steps as f64)),
            ("repins", Json::num(snap.repins as f64)),
            ("repin_us_sum", Json::num(snap.repin_ns_sum / 1e3)),
            ("kv_peak_pages", Json::num(report.kv_peak_pages as f64)),
            ("kv_capacity_pages", Json::num(report.kv_capacity_pages as f64)),
        ]));
    }

    // Armed preemption overload leg (DESIGN.md §18).  Light load first:
    // with the same capped pager and batching window, `off` and `auto`
    // must be bit-identical — nothing ever arms the preemption path.
    let leg = preempt_leg(spec);
    let leg_run = |gap: f64, policy: PreemptPolicy| -> (ServeReport, MetricsSnapshot) {
        let plan = ArrivalPlan::poisson(SEED, gap, REQUESTS, spec.cfg.max_seq);
        let mf = Manifest::load(&dir).unwrap();
        let router = Router::new(rt, mf, spec.name).unwrap();
        let batch_policy = BatchPolicy::new(router.batch_sizes())
            .unwrap()
            .with_max_wait_us(leg.max_wait_us);
        let mut server = Server::new(router, Batcher::new(batch_policy));
        let opts = ServeOptions::new(BATCH, CHUNK)
            .with_queue_cap(QUEUE_CAP)
            .with_kv_capacity_bytes(leg.capacity_bytes)
            .with_preempt(policy);
        let report = server.serve_load(&plan, &opts).expect("serve_load");
        assert!(report.kv_idle, "kv pager must drain");
        let snap = server.metrics.snapshot();
        assert!(snap.outcomes_accounted(), "conservation violated: {snap:?}");
        assert!(snap.sheds_accounted(), "typed sheds must close: {snap:?}");
        assert!(snap.preemptions_accounted(), "preemption ledger must close: {snap:?}");
        (report, snap)
    };
    let leg_cell = |model: &str, pol: &str, report: &ServeReport, snap: &MetricsSnapshot| -> Json {
        Json::obj(vec![
            ("model", Json::str(model)),
            ("moe", Json::Bool(spec.cfg.moe_experts > 0)),
            ("mean_gap_us", Json::num(PREEMPT_GAP_US)),
            ("preempt", Json::str(pol)),
            ("max_wait_us", Json::num(leg.max_wait_us as f64)),
            (
                "goodput_tok_per_s",
                Json::num(snap.goodput_tokens_per_s(report.horizon_us)),
            ),
            ("horizon_us", Json::num(report.horizon_us as f64)),
            ("admitted", Json::num(snap.requests_admitted as f64)),
            ("completed", Json::num(snap.requests_completed as f64)),
            ("shed", Json::num(snap.requests_shed as f64)),
            (
                "shed_queue_full",
                Json::num(snap.shed_reasons.get("queue_full").copied().unwrap_or(0) as f64),
            ),
            (
                "shed_kv_capacity",
                Json::num(snap.shed_reasons.get("kv_capacity").copied().unwrap_or(0) as f64),
            ),
            ("tokens_generated", Json::num(snap.tokens_generated as f64)),
            ("ttft_p50_us", Json::num(snap.serve_ttft_us.p50)),
            ("ttft_p99_us", Json::num(snap.serve_ttft_us.p99)),
            ("tok_gap_p50_us", Json::num(snap.serve_token_gap_us.p50)),
            ("tok_gap_p99_us", Json::num(snap.serve_token_gap_us.p99)),
            ("prefill_steps", Json::num(snap.prefill_steps as f64)),
            ("decode_steps", Json::num(snap.decode_steps as f64)),
            ("preempted", Json::num(snap.requests_preempted as f64)),
            ("resumed", Json::num(snap.requests_resumed as f64)),
            ("swap_bytes", Json::num(snap.swap_bytes as f64)),
            ("preempt_swap_us", Json::num(snap.swap_us_sum as f64)),
            ("recompute_ticks", Json::num(snap.recompute_ticks as f64)),
            ("preempt_recompute_us", Json::num(snap.recompute_us_sum as f64)),
            ("kv_peak_pages", Json::num(report.kv_peak_pages as f64)),
            ("kv_capacity_pages", Json::num(report.kv_capacity_pages as f64)),
        ])
    };
    let (light_off_rep, light_off_snap) = leg_run(leg.light_gap_us, PreemptPolicy::Off);
    let (light_auto_rep, light_auto_snap) = leg_run(leg.light_gap_us, PreemptPolicy::Auto);
    assert_eq!(light_auto_snap.requests_preempted, 0, "light load must not arm preemption");
    assert_eq!(
        leg_cell("light", "off", &light_off_rep, &light_off_snap).to_string(),
        leg_cell("light", "off", &light_auto_rep, &light_auto_snap).to_string(),
        "{}: light-load serve must be preemption-invariant",
        spec.name,
    );
    let (off_rep, off_snap) = leg_run(PREEMPT_GAP_US, PreemptPolicy::Off);
    let (auto_rep, auto_snap) = leg_run(PREEMPT_GAP_US, PreemptPolicy::Auto);
    let goodput_off = off_snap.goodput_tokens_per_s(off_rep.horizon_us);
    let goodput_auto = auto_snap.goodput_tokens_per_s(auto_rep.horizon_us);
    println!(
        "preempt leg gap={PREEMPT_GAP_US:.0} us  off goodput {goodput_off:>9.0} tok/s \
         p99 {:>8.0} us  |  auto goodput {goodput_auto:>9.0} tok/s p99 {:>8.0} us  \
         ({} preempted, {} swap B, {} recompute ticks)",
        off_snap.serve_ttft_us.p99,
        auto_snap.serve_ttft_us.p99,
        auto_snap.requests_preempted,
        auto_snap.swap_bytes,
        auto_snap.recompute_ticks,
    );
    assert!(
        goodput_auto > goodput_off,
        "{}: auto goodput must strictly beat off at deep overload ({goodput_auto} vs {goodput_off})",
        spec.name,
    );
    assert!(
        auto_snap.serve_ttft_us.p99 < off_snap.serve_ttft_us.p99,
        "{}: auto p99 TTFT must strictly beat off at deep overload ({} vs {})",
        spec.name,
        auto_snap.serve_ttft_us.p99,
        off_snap.serve_ttft_us.p99,
    );
    cells.push(leg_cell(&format!("{}+preempt-off", spec.name), "off", &off_rep, &off_snap));
    cells.push(leg_cell(&format!("{}+preempt-auto", spec.name), "auto", &auto_rep, &auto_snap));
    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    let machine = MachineConfig::ascend910();
    let rt = Runtime::cpu().expect("cpu runtime");
    let mut cells = Vec::new();
    for spec in models() {
        bench_model(&rt, &machine, &spec, &mut cells);
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("e2e_serve")),
        ("batch", Json::num(BATCH as f64)),
        ("chunk", Json::num(CHUNK as f64)),
        ("queue_cap", Json::num(QUEUE_CAP as f64)),
        ("requests", Json::num(REQUESTS as f64)),
        ("seed", Json::num(SEED as f64)),
        ("cells", Json::arr(cells)),
    ]);
    std::fs::create_dir_all("target").expect("target dir");
    let out = "target/BENCH_serve.json";
    std::fs::write(out, doc.to_string()).expect("write json");
    println!("\nwrote {out}");
}
