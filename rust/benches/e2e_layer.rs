//! Decode-layer graph bench: simulate all four projection GEMMs (qkv,
//! attn_out, up_gate, down) per paper model and batch size, every node
//! resolved through the autotuner, and track what the pipelined reduce
//! buys over Algorithm 1's barrier reduce at the whole-layer level — the
//! granularity LiquidGEMM and Multi-Scale Dequant evaluate at.
//!
//! Emits a machine-readable `target/BENCH_layer.json` so the per-layer
//! latency trajectory is tracked across PRs.
//!
//! Run with `cargo bench --bench e2e_layer`.

use ascend_w4a16::analysis::layer;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::bench::section;
use ascend_w4a16::model::llm::paper_layer_geometries;
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::util::json::Json;
use ascend_w4a16::workload::DecodeLayer;

fn main() {
    let machine = MachineConfig::ascend910();
    let mut tuner = Tuner::new(machine.clone());
    let mut cells = Vec::new();

    for (model, geom) in paper_layer_geometries() {
        section(&format!("decode layer — {model} (simulated, tuned per node)"));
        for batch in [1usize, 8, 64] {
            let decode_layer = DecodeLayer::new(geom, batch);
            let rep = layer::simulate_layer_tuned(&machine, &decode_layer, &mut tuner)
                .expect("simulate layer");
            let speedup = rep.layer_barrier_ns() / rep.layer_ns();
            let strategies: Vec<String> = rep
                .nodes
                .iter()
                .map(|n| format!("{}={}", n.kind.name(), n.strategy.name()))
                .collect();
            println!(
                "b={batch:<3} layer {:>10.2} us  (barrier-reduce {:>10.2} us, {:.3}x)  {}",
                rep.layer_ns() / 1e3,
                rep.layer_barrier_ns() / 1e3,
                speedup,
                strategies.join(" "),
            );
            cells.push(Json::obj(vec![
                ("model", Json::str(model)),
                ("batch", Json::num(batch as f64)),
                ("layer_us", Json::num(rep.layer_ns() / 1e3)),
                ("layer_barrier_us", Json::num(rep.layer_barrier_ns() / 1e3)),
                ("reduce_pipeline_speedup", Json::num(speedup)),
                ("detail", layer::layer_json(&rep)),
            ]));
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("e2e_layer")),
        ("cells", Json::arr(cells)),
    ]);
    std::fs::create_dir_all("target").expect("target dir");
    let out = "target/BENCH_layer.json";
    std::fs::write(out, doc.to_string()).expect("write json");
    println!("\nwrote {out}");
}
