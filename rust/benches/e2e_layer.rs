//! Decode-layer / decode-step bench: simulate every paper model — dense
//! trunks AND the MoE decoding scenario — with every GEMM node resolved
//! through the autotuner, and track (a) what the pipelined reduce buys
//! over Algorithm 1's barrier reduce at the whole-layer level and (b)
//! what the cross-node reduce/dequant overlap ledger buys over the
//! sequential chain at the full-step level — the granularity LiquidGEMM
//! and Multi-Scale Dequant evaluate at.
//!
//! Emits a machine-readable `target/BENCH_layer.json` so the per-layer
//! and per-step latency trajectories are tracked across PRs.
//!
//! Run with `cargo bench --bench e2e_layer`.

use ascend_w4a16::analysis::layer::{self, OverlapMode};
use ascend_w4a16::analysis::residency::ResidencyMode;
use ascend_w4a16::analysis::stepsim::StepSim;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::bench::section;
use ascend_w4a16::kernels::GemmProblem;
use ascend_w4a16::model::llm::{
    layer_geometry, moe_geometry, paper_layer_geometries, paper_moe_geometries, paper_shapes,
    MoeGeometry,
};
use ascend_w4a16::model::Precision;
use ascend_w4a16::tune::{self, Tuner};
use ascend_w4a16::util::json::Json;
use ascend_w4a16::workload::{DecodeLayer, DecodeStep};

const KV_LEN: usize = 2048;

fn bench_model(
    machine: &MachineConfig,
    tuner: &mut Tuner,
    model: &str,
    geom: ascend_w4a16::model::llm::LayerGeometry,
    moe: Option<MoeGeometry>,
    cells: &mut Vec<Json>,
) {
    section(&format!(
        "decode {} — {model} (simulated, tuned per node)",
        if moe.is_some() { "step [MoE]" } else { "step" }
    ));
    for batch in [1usize, 8, 64] {
        let mut decode_layer = DecodeLayer::new(geom, batch);
        if let Some(moe) = moe {
            decode_layer = decode_layer.with_moe(moe);
        }
        let step = DecodeStep::new(decode_layer, KV_LEN, DecodeStep::default_heads(&geom));
        let srep = StepSim::new(machine, &step)
            .overlap(OverlapMode::Auto)
            .residency(ResidencyMode::Auto)
            .tuner(tuner)
            .run()
            .expect("simulate step");
        // The step's GEMM sub-chain IS the layer report — no second pass.
        let rep = srep.gemm_report();
        let reduce_speedup = rep.layer_barrier_ns() / rep.layer_ns();
        let overlap_speedup = srep.sequential_ns / srep.served_ns();
        // What the phase-level co-scheduler buys over the sequential chain
        // (DESIGN.md §12) — and over PR 3's first-order ledger.
        let overlap_exact_speedup = srep.sequential_ns / srep.exact_ns;
        let exact_vs_ledger = srep.overlapped_ns / srep.exact_ns;
        // What the step-level weight-residency plan buys over the PR-4
        // Auto plan (DESIGN.md §13): served = min(auto, resident), so the
        // speedup is >= 1 by construction.
        let auto_base = srep.auto_ns();
        let resident_us = srep.resident_ns().unwrap_or(auto_base) / 1e3;
        let residency_speedup = auto_base / srep.served_ns();
        let strategies: Vec<String> = rep
            .nodes
            .iter()
            .map(|n| format!("{}={}", n.kind.name(), n.strategy.name()))
            .collect();
        println!(
            "b={batch:<3} gemm {:>9.2} us (barrier {:>9.2} us, {:.3}x)  \
             step {:>9.2} us (seq {:>9.2} us, ledger {:.3}x, exact {:.3}x, \
             resident {:.3}x)  {}",
            rep.layer_ns() / 1e3,
            rep.layer_barrier_ns() / 1e3,
            reduce_speedup,
            srep.served_ns() / 1e3,
            srep.sequential_ns / 1e3,
            overlap_speedup,
            overlap_exact_speedup,
            residency_speedup,
            strategies.join(" "),
        );
        cells.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("moe", Json::Bool(moe.is_some())),
            ("batch", Json::num(batch as f64)),
            ("layer_us", Json::num(rep.layer_ns() / 1e3)),
            ("layer_barrier_us", Json::num(rep.layer_barrier_ns() / 1e3)),
            ("reduce_pipeline_speedup", Json::num(reduce_speedup)),
            ("step_us", Json::num(srep.served_ns() / 1e3)),
            ("step_sequential_us", Json::num(srep.sequential_ns / 1e3)),
            ("step_exact_us", Json::num(srep.exact_ns / 1e3)),
            ("step_resident_us", Json::num(resident_us)),
            ("residency_speedup", Json::num(residency_speedup)),
            ("residency_gain_us", Json::num(srep.residency_gain_ns() / 1e3)),
            (
                "residency_pinned_bytes",
                Json::num(srep.residency.as_ref().map(|p| p.pinned_bytes as f64).unwrap_or(0.0)),
            ),
            ("overlap_speedup", Json::num(overlap_speedup)),
            ("overlap_exact_speedup", Json::num(overlap_exact_speedup)),
            ("overlap_exact_vs_ledger", Json::num(exact_vs_ledger)),
            ("overlap_gain_us", Json::num(srep.overlap_gain_ns() / 1e3)),
            ("overlap_exact_gain_us", Json::num(srep.exact_gain_ns() / 1e3)),
            ("detail", layer::layer_json(&rep)),
            ("step_detail", layer::step_json(&srep)),
        ]));
    }
}

/// Co-scheduler stress leg: force a K split on every node so each carries
/// an exposed reduce tail (the tuned sweep above legitimately picks
/// reduce-free winners on most shapes, leaving nothing to overlap) — this
/// is where `overlap_exact_speedup` strictly beats 1.0 and the exact
/// pricing separates from the first-order ledger (DESIGN.md §12).
fn bench_forced_split(machine: &MachineConfig, model: &str, cells: &mut Vec<Json>) {
    let geom = layer_geometry(model).expect("paper model");
    let mut decode_layer = DecodeLayer::new(geom, 8);
    if let Some(moe) = moe_geometry(model) {
        decode_layer = decode_layer.with_moe(moe);
    }
    let step = DecodeStep::new(decode_layer, 2048, DecodeStep::default_heads(&geom));
    let srep = StepSim::new(machine, &step)
        .overlap(OverlapMode::Auto)
        .residency(ResidencyMode::Auto)
        .resolver(layer::forced_split_resolver(machine))
        .run()
        .expect("simulate forced-split step");
    let exact_speedup = srep.sequential_ns / srep.exact_ns;
    let auto_base = srep.auto_ns();
    println!(
        "{model:<14} b=8  step {:>9.2} us (seq {:>9.2} us, ledger {:.3}x, exact {:.3}x, \
         resident {:.3}x)",
        srep.served_ns() / 1e3,
        srep.sequential_ns / 1e3,
        srep.sequential_ns / srep.overlapped_ns,
        exact_speedup,
        auto_base / srep.served_ns(),
    );
    cells.push(Json::obj(vec![
        ("model", Json::str(format!("{model}-forced-split"))),
        ("moe", Json::Bool(moe_geometry(model).is_some())),
        ("batch", Json::num(8.0)),
        ("step_us", Json::num(srep.served_ns() / 1e3)),
        ("step_sequential_us", Json::num(srep.sequential_ns / 1e3)),
        ("step_exact_us", Json::num(srep.exact_ns / 1e3)),
        ("step_resident_us", Json::num(srep.resident_ns().unwrap_or(auto_base) / 1e3)),
        ("residency_speedup", Json::num(auto_base / srep.served_ns())),
        ("residency_gain_us", Json::num(srep.residency_gain_ns() / 1e3)),
        ("overlap_speedup", Json::num(srep.sequential_ns / srep.overlapped_ns)),
        ("overlap_exact_speedup", Json::num(exact_speedup)),
        ("overlap_exact_vs_ledger", Json::num(srep.overlapped_ns / srep.exact_ns)),
        ("overlap_gain_us", Json::num(srep.overlap_gain_ns() / 1e3)),
        ("overlap_exact_gain_us", Json::num(srep.exact_gain_ns() / 1e3)),
        ("step_detail", layer::step_json(&srep)),
    ]));
}

/// Precision-family sweep: the tuned W4A16 winner vs the tuned
/// W4A8-tagged winner (Auto over all six strategies, so the W4A8 column
/// is never slower by construction — the W4A16 family stays searchable)
/// for every paper shape at batch 8, plus the paper's headline decode
/// shape.  `w4a8_us`/`w4a16_us` gate in bench-diff; `w4a8_speedup` is a
/// ratio and never gates.
fn bench_precision_sweep(machine: &MachineConfig, cells: &mut Vec<Json>) {
    section("precision family — tuned W4A16 vs tuned W4A8 (batch 8)");
    let mut shapes: Vec<(String, usize, usize)> = paper_shapes()
        .iter()
        .map(|s| (s.model.to_string(), s.n, s.k))
        .collect();
    shapes.push(("decode".to_string(), 512, 16384));
    for (model, n, k) in shapes {
        let batch = 8usize;
        let a16 = tune::search(machine, &GemmProblem::new(batch, n, k))
            .expect("w4a16 search")
            .best;
        let p8 = GemmProblem::new(batch, n, k).with_precision(Precision::W4A8);
        let a8 = tune::search(machine, &p8).expect("w4a8 search").best;
        let speedup = a16.total_ns / a8.total_ns;
        println!(
            "{model:<10} n={n:<6} k={k:<6} w4a16 {:>9.2} us ({}) -> w4a8 {:>9.2} us ({}) \
             {speedup:.3}x",
            a16.total_ns / 1e3,
            a16.strategy.name(),
            a8.total_ns / 1e3,
            a8.strategy.name(),
        );
        cells.push(Json::obj(vec![
            ("model", Json::str(format!("{model}:{n}x{k}"))),
            ("n", Json::num(n as f64)),
            ("k", Json::num(k as f64)),
            ("batch", Json::num(batch as f64)),
            ("w4a16_us", Json::num(a16.total_ns / 1e3)),
            ("w4a16_strategy", Json::str(a16.strategy.name())),
            ("w4a8_us", Json::num(a8.total_ns / 1e3)),
            ("w4a8_strategy", Json::str(a8.strategy.name())),
            ("w4a8_speedup", Json::num(speedup)),
        ]));
    }
}

fn main() {
    let machine = MachineConfig::ascend910();
    let mut tuner = Tuner::new(machine.clone());
    let mut cells = Vec::new();

    for (model, geom) in paper_layer_geometries() {
        bench_model(&machine, &mut tuner, model, geom, None, &mut cells);
    }
    for (model, geom, moe) in paper_moe_geometries() {
        bench_model(&machine, &mut tuner, model, geom, Some(moe), &mut cells);
    }

    section("co-scheduler stress — forced K-splits (exact vs ledger overlap)");
    for model in ["llama32", "deepseek-moe"] {
        bench_forced_split(&machine, model, &mut cells);
    }

    bench_precision_sweep(&machine, &mut cells);

    let doc = Json::obj(vec![
        ("bench", Json::str("e2e_layer")),
        ("kv_len", Json::num(KV_LEN as f64)),
        ("cells", Json::arr(cells)),
    ]);
    std::fs::create_dir_all("target").expect("target dir");
    let out = "target/BENCH_layer.json";
    std::fs::write(out, doc.to_string()).expect("write json");
    println!("\nwrote {out}");
}
