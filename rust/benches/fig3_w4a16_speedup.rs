//! Bench: regenerate the paper's **Figure 3** — speedup of the Split-K
//! W4A16 kernel over native FP16xFP16 matmul across N x K configurations
//! and batch sizes (simulated Ascend 910).
//!
//! Expected shape (paper §4.2): the speedup peaks around ~1.5x — far below
//! the theoretical ~4x from the weight-size reduction — because the
//! dequantized weights make an extra memory round trip between the
//! decoupled vector and cube units; oversized workspaces spill L2 and drop
//! below 1x.  Run with `cargo bench --bench fig3_w4a16_speedup`.

use ascend_w4a16::analysis::report;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::bench::{section, Bench};

fn main() {
    let machine = MachineConfig::ascend910();

    section("Figure 3 sweep (simulated)");
    let cells = report::fig3_sweep(&machine).expect("sweep");
    print!("{}", report::render_fig3(&cells));

    let out = "target/fig3.json";
    std::fs::write(out, report::fig3_json(&cells).to_string()).expect("write json");
    println!("\nwrote {out}");

    section("harness wallclock (simulator throughput)");
    let r = Bench::new("fig3 full sweep (84 cells x 2 strategies)")
        .warmup(1)
        .iters(5)
        .run(|| {
            std::hint::black_box(report::fig3_sweep(&machine).unwrap());
        });
    println!("{}", r.render_row());
}
