//! Bench: the chunk-pipelined Split-K ablation — chunked vs Algorithm 1
//! (splitk) vs native FP16 across the paper's shape sweep, plus the
//! Workspace HBM traffic each schedule moves (the §4.2 bottleneck in
//! bytes).  Emits a machine-readable `target/BENCH_chunked.json` so the
//! perf trajectory is tracked across PRs.
//!
//! Run with `cargo bench --bench ablation_chunked`.

use ascend_w4a16::analysis::report;
use ascend_w4a16::ascend::MachineConfig;
use ascend_w4a16::bench::{section, Bench};
use ascend_w4a16::tune::Tuner;
use ascend_w4a16::util::json::Json;
use ascend_w4a16::util::stats;
use ascend_w4a16::kernels::GemmProblem;

fn main() {
    let machine = MachineConfig::ascend910();

    section("chunked ablation sweep (simulated)");
    let cells = report::chunked_sweep(&machine).expect("sweep");
    print!("{}", report::render_chunked(&cells));

    // Tuned (auto) comparison on the acceptance decode shape.
    section("tuned schedule on the decode bottleneck shape");
    let mut tuner = Tuner::new(machine.clone());
    let p = GemmProblem::new(8, 512, 16384);
    let e = tuner.resolve(&p).expect("tune");
    println!(
        "M=8 N=512 K=16384 -> {} (S={}, C={}) at {}",
        e.strategy.name(),
        e.tiling.splits,
        e.tiling.chunks,
        stats::fmt_ns(e.total_ns)
    );

    // Machine-readable snapshot for cross-PR trajectory tracking.
    let kd: Vec<f64> = cells
        .iter()
        .filter(|c| c.k >= 2 * c.n)
        .map(|c| c.speedup_vs_splitk())
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("ablation_chunked")),
        ("cells", report::chunked_json(&cells)),
        ("geomean_speedup_vs_splitk_k_dominant", Json::num(stats::geomean(&kd))),
        (
            "ws_hbm_bytes_splitk_total",
            Json::num(cells.iter().map(|c| c.ws_hbm_splitk).sum()),
        ),
        (
            "ws_hbm_bytes_chunked_total",
            Json::num(cells.iter().map(|c| c.ws_hbm_chunked).sum()),
        ),
        ("tuned_decode_strategy", Json::str(e.strategy.name())),
        ("tuned_decode_ns", Json::num(e.total_ns)),
    ]);
    std::fs::create_dir_all("target").expect("target dir");
    let out = "target/BENCH_chunked.json";
    std::fs::write(out, doc.to_string()).expect("write json");
    println!("\nwrote {out}");

    section("harness wallclock (simulator throughput)");
    let r = Bench::new("chunked sweep (84 cells x 3 strategies)")
        .warmup(1)
        .iters(3)
        .run(|| {
            std::hint::black_box(report::chunked_sweep(&machine).unwrap());
        });
    println!("{}", r.render_row());
}
