//! Ablation A — the paper's future-work hypothesis, quantified.
//!
//! §5: "Future work should explore ... direct data paths between vector
//! and cube units or fused instructions that bypass global memory".  This
//! bench compares, per paper shape at decode batch M=8:
//!   * native FP16 (baseline),
//!   * three-phase Split-K W4A16 (Algorithm 1, with the round trip),
//!   * the hypothetical fused direct path (no workspace).
//! The fused column should approach the theoretical ~4x that Algorithm 1
//! cannot reach.  Run with `cargo bench --bench ablation_fused`.

use ascend_w4a16::ascend::{MachineConfig, Simulator};
use ascend_w4a16::bench::section;
use ascend_w4a16::kernels::{self, Strategy};
use ascend_w4a16::model::llm::paper_shapes;
use ascend_w4a16::util::stats;
use ascend_w4a16::workload::problem_for;

fn main() {
    let machine = MachineConfig::ascend910();
    let sim = Simulator::new(machine.clone());
    const M: usize = 8;

    section("Ablation A: fused direct path vs Algorithm 1 (M=8, simulated µs)");
    println!(
        "{:<12} {:>6} {:>6} | {:>9} {:>9} {:>9} | {:>9} {:>9}",
        "model", "N", "K", "fp16", "splitk", "fused", "sk_spdup", "fu_spdup"
    );
    let mut sk_speedups = Vec::new();
    let mut fu_speedups = Vec::new();
    for shape in paper_shapes() {
        let p = problem_for(&shape, M);
        let fp16 = sim.run(&kernels::schedule(&machine, &p, Strategy::Fp16Native).unwrap()).unwrap();
        let sk = sim.run(&kernels::schedule(&machine, &p, Strategy::SplitK).unwrap()).unwrap();
        let fu = sim.run(&kernels::schedule(&machine, &p, Strategy::Fused).unwrap()).unwrap();
        let sk_spdup = fp16.total_ns / sk.total_ns;
        let fu_spdup = fp16.total_ns / fu.total_ns;
        sk_speedups.push(sk_spdup);
        fu_speedups.push(fu_spdup);
        println!(
            "{:<12} {:>6} {:>6} | {:>9.2} {:>9.2} {:>9.2} | {:>8.2}x {:>8.2}x",
            shape.model, shape.n, shape.k,
            fp16.total_ns / 1e3, sk.total_ns / 1e3, fu.total_ns / 1e3,
            sk_spdup, fu_spdup,
        );
    }
    println!(
        "\ngeomean: splitk {:.2}x, fused {:.2}x (theoretical weight-traffic bound ~4x)",
        stats::geomean(&sk_speedups),
        stats::geomean(&fu_speedups),
    );
    println!(
        "=> the workspace round trip costs {:.0}% of the attainable W4A16 speedup on this machine",
        100.0 * (1.0 - stats::geomean(&sk_speedups) / stats::geomean(&fu_speedups)),
    );
}
